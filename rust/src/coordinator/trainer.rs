//! The paper's Algorithm 1: round-robin split-learning training with
//! adaptive feature-wise compression on both links.
//!
//! One step (t, k):
//!   1. device k draws a minibatch, runs `device_fwd` → F                (eq. 3)
//!   2. `feature_stats` (the σ-statistics kernel) → σ_norm              (eq. 10)
//!   3. FWDP + FWQ encode → uplink frame → PS decodes F̂            (Alg. 2/3)
//!   4. PS runs `server_fwd_bwd` → loss, ∇w_s, G = ∇_F̂ h          (eqs. 4, 5)
//!   5. PS ADAM-steps w_s; PS drops non-kept gradient columns, FWQ-encodes,
//!      downlink frame → device decodes Ĝ                             (eq. 8)
//!   6. device applies the chain-rule scale δ_j/(1-p_j) to Ĝ, runs
//!      `device_bwd` → ∇w_d; the (PS-held) device ADAM steps w_d (Sec. III-A)
//!
//! Every model computation goes through the [`Backend`] trait: the pure-Rust
//! native backend by default, or pre-compiled HLO artifacts through the PJRT
//! CPU client under `--features pjrt`.

use std::time::Instant;

use crate::compression::{
    encode_downlink, encode_uplink, CodecParams, DropKind, GradMask, Scheme,
};
use crate::config::{PartitionKind, TrainConfig};
use crate::coordinator::metrics::{MetricsWriter, StepRecord, TrainSummary};
use crate::data::{
    dirichlet_partition, label_shards, writer_groups, Dataset, MiniBatchLoader, SynthSpec,
};
use crate::model::PresetInfo;
use crate::optim::{Adam, Optimizer};
use crate::runtime::{create_backend, Backend};
use crate::tensor::Matrix;
use crate::transport::{Direction, Link};
use crate::util::error::{Context, Result};
use crate::util::Rng;
use crate::{ensure, log_debug, log_info};

pub struct Trainer {
    pub cfg: TrainConfig,
    pub backend: Box<dyn Backend>,
    preset: PresetInfo,
    wd: crate::model::ParamSet,
    ws: crate::model::ParamSet,
    opt_d: Adam,
    opt_s: Adam,
    train: Dataset,
    test: Dataset,
    loaders: Vec<MiniBatchLoader>,
    pub link: Link,
    rng: Rng,
    metrics: MetricsWriter,
    exec_s: f64,
}

fn synth_spec_for(preset: &str) -> SynthSpec {
    match preset {
        "mnist" => SynthSpec::mnist_like(),
        "cifar" => SynthSpec::cifar_like(),
        "celeba" => SynthSpec::celeba_like(),
        _ => SynthSpec::tiny(),
    }
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        // size the parallel runtime (matmul blocks, FWQ planning) for this
        // run; 0 = unset, which leaves the process-global pool alone (auto
        // by default) so library callers' explicit set_threads survives
        if cfg.threads > 0 {
            crate::util::par::set_threads(cfg.threads);
        }
        let backend = create_backend(cfg.backend, &cfg.artifacts_dir, &cfg.preset)?;
        let preset = backend.preset().clone();
        let (wd, ws) = backend.init_params()?;
        ensure!(wd.n_params() == preset.nd_params);
        ensure!(ws.n_params() == preset.ns_params);

        let mut rng = Rng::new(cfg.seed.wrapping_mul(0x9E3779B9).wrapping_add(7));
        let spec = synth_spec_for(&cfg.preset);
        // consistency between model input shape and dataset spec
        ensure!(
            spec.sample_dim() == preset.sample_dim(),
            "dataset spec {:?} vs model input {:?}",
            (spec.channels, spec.height, spec.width),
            preset.in_shape
        );
        let train = Dataset::generate(&spec, cfg.n_train, cfg.seed);
        let test = Dataset::generate(&spec, cfg.n_test, cfg.seed.wrapping_add(0xE7A1));

        let parts = match cfg.partition {
            PartitionKind::LabelShards => label_shards(&train, cfg.devices, 2, &mut rng),
            PartitionKind::Dirichlet => dirichlet_partition(&train, cfg.devices, 0.3, &mut rng),
            PartitionKind::Writers => writer_groups(&train, cfg.devices, &mut rng),
        };
        let loaders = parts
            .into_iter()
            .enumerate()
            .map(|(k, mut p)| {
                if p.is_empty() {
                    // degenerate partition (tiny runs): give it one sample
                    p.push(k % train.n);
                }
                MiniBatchLoader::new(p, preset.batch, rng.fork(k as u64))
            })
            .collect();

        let opt_d = Adam::new(cfg.lr, wd.n_params());
        let opt_s = Adam::new(cfg.lr, ws.n_params());
        let link = Link::new(cfg.link_capacity_bps, cfg.link_latency_s);
        let metrics = MetricsWriter::create(&cfg.metrics_path);
        Ok(Trainer {
            rng: rng.fork(0xFFFF),
            cfg,
            backend,
            preset,
            wd,
            ws,
            opt_d,
            opt_s,
            train,
            test,
            loaders,
            link,
            metrics,
            exec_s: 0.0,
        })
    }

    /// Static description of the loaded model (shapes, parameter layout).
    pub fn preset(&self) -> &PresetInfo {
        &self.preset
    }

    /// Does the current scheme need σ statistics (the feature_stats kernel)?
    fn needs_sigma(scheme: &Scheme) -> bool {
        matches!(
            scheme,
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), .. }
                | Scheme::SplitFc { drop: Some(DropKind::Deterministic), .. }
        )
    }

    /// Run one (t, k) protocol step.
    pub fn step(&mut self, round: usize, device: usize) -> Result<StepRecord> {
        let t_step = Instant::now();
        let exec_before = self.exec_s;
        let p = self.preset.clone();
        let scheme = self.cfg.scheme.clone();

        // 1. device forward
        let (x, y, _) = self.loaders[device].next_batch(&self.train, p.classes);
        let t0 = Instant::now();
        let f = self.backend.device_fwd(&self.wd, &x)?;
        self.exec_s += t0.elapsed().as_secs_f64();

        // 2. feature statistics (σ of the channel-normalized columns, eq. 10)
        let sigma: Vec<f32> = if Self::needs_sigma(&scheme) {
            let t0 = Instant::now();
            let s = self.backend.feature_stats(&f)?;
            self.exec_s += t0.elapsed().as_secs_f64();
            s
        } else {
            vec![0.0; p.dbar]
        };

        // 3. uplink compression + transmit
        let up_params = CodecParams::new(p.batch, p.dbar, self.cfg.up_bits_per_entry);
        let enc = encode_uplink(&scheme, &f, &sigma, &up_params, &mut self.rng);
        self.link.transmit(Direction::Uplink, &enc.frame);

        // 4. server forward/backward
        let t0 = Instant::now();
        let out = self.backend.server_fwd_bwd(&self.ws, &enc.f_hat, &y)?;
        self.exec_s += t0.elapsed().as_secs_f64();

        // 5. server update + downlink compression
        self.opt_s.step(&mut self.ws.data, &out.grad_ws);
        let down_params = CodecParams::new(p.batch, p.dbar, self.cfg.down_bits_per_entry);
        let dn = encode_downlink(&scheme, &out.g, &enc.mask, &down_params);
        self.link.transmit(Direction::Downlink, &dn.frame);

        // 6. device backward with the chain-rule scale (eq. 7 backward path)
        let mut g_hat = dn.g_hat;
        if let GradMask::Columns { kept, scale } = &enc.mask {
            g_hat.scale_cols(kept, scale);
        }
        let t0 = Instant::now();
        let grad_wd = self.backend.device_bwd(&self.wd, &x, &g_hat)?;
        self.exec_s += t0.elapsed().as_secs_f64();
        self.opt_d.step(&mut self.wd.data, &grad_wd);

        let rec = StepRecord {
            round,
            device,
            loss: out.loss,
            train_acc: out.correct / p.batch as f32,
            up_bits: enc.frame.payload_bits,
            down_bits: dn.frame.payload_bits,
            up_nominal: enc.nominal_bits,
            down_nominal: dn.nominal_bits,
            step_s: t_step.elapsed().as_secs_f64(),
            exec_s: self.exec_s - exec_before,
        };
        self.metrics.write(&rec.to_json());
        Ok(rec)
    }

    /// Test-set accuracy via the backend's full-model forward.
    pub fn evaluate(&mut self) -> Result<f32> {
        let p = self.preset.clone();
        let dim = p.sample_dim();
        let n_batches = (self.test.n / p.batch).max(1);
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let mut x = Vec::with_capacity(p.batch * dim);
            let mut labels = Vec::with_capacity(p.batch);
            for j in 0..p.batch {
                let i = (bi * p.batch + j) % self.test.n;
                x.extend_from_slice(self.test.sample(i));
                labels.push(self.test.y[i]);
            }
            let t0 = Instant::now();
            let logits = self.backend.eval_logits(&self.wd, &self.ws, &x)?;
            self.exec_s += t0.elapsed().as_secs_f64();
            for (j, &lab) in labels.iter().enumerate() {
                let row = &logits[j * p.classes..(j + 1) * p.classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                correct += (pred == lab as usize) as usize;
                total += 1;
            }
        }
        Ok(correct as f32 / total as f32)
    }

    /// Full training run: T rounds of round-robin over K devices (Alg. 1).
    pub fn run(&mut self) -> Result<TrainSummary> {
        let t0 = Instant::now();
        let mut summary = TrainSummary::default();
        let mut last_round_losses = Vec::new();
        for t in 1..=self.cfg.rounds {
            last_round_losses.clear();
            for k in 0..self.cfg.devices {
                let rec = self
                    .step(t, k)
                    .with_context(|| format!("step t={t} k={k}"))?;
                summary.total_up_bits += rec.up_bits;
                summary.total_down_bits += rec.down_bits;
                summary.steps += 1;
                last_round_losses.push(rec.loss);
                log_debug!(
                    "t={t} k={k} loss={:.4} acc={:.3} up={}b down={}b",
                    rec.loss,
                    rec.train_acc,
                    rec.up_bits,
                    rec.down_bits
                );
            }
            if self.cfg.eval_every > 0 && t % self.cfg.eval_every == 0 {
                let acc = self.evaluate()?;
                summary.eval_history.push((t, acc));
                log_info!("round {t}: eval acc {:.4}", acc);
            }
        }
        summary.final_acc = self.evaluate()?;
        summary.eval_history.push((self.cfg.rounds, summary.final_acc));
        summary.mean_loss_last_round = if last_round_losses.is_empty() {
            f32::NAN
        } else {
            last_round_losses.iter().sum::<f32>() / last_round_losses.len() as f32
        };
        summary.wall_s = t0.elapsed().as_secs_f64();
        summary.exec_s = self.exec_s;
        summary.link_s = self.link.report().elapsed_s;
        self.metrics.write(&summary.to_json());
        self.metrics.flush();
        Ok(summary)
    }

    /// The features + σ stats of one fresh batch (Fig.-1 dispersion bench).
    pub fn probe_features(&mut self, device: usize) -> Result<(Matrix, Vec<f32>)> {
        let p = self.preset.clone();
        let (x, _, _) = self.loaders[device].next_batch(&self.train, p.classes);
        let t0 = Instant::now();
        let f = self.backend.device_fwd(&self.wd, &x)?;
        let sigma = self.backend.feature_stats(&f)?;
        self.exec_s += t0.elapsed().as_secs_f64();
        Ok((f, sigma))
    }
}
