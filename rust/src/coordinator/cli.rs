//! The `splitfc` command-line interface (leader entrypoint).

use crate::config::TrainConfig;
use crate::coordinator::trainer::run_remote_device;
use crate::coordinator::{experiments, trainer::Trainer};
use crate::transport::channel::vanilla_sl_transfer_time_s;
use crate::transport::TransportKind;
use crate::util::error::Result;
use crate::util::Args;

const HELP: &str = "\
splitfc — communication-efficient split learning (SplitFC reproduction)

USAGE:
  splitfc train --preset <tiny|mnist|cifar|celeba> [--scheme S] [--r R]
                [--up-bpe X] [--down-bpe X] [--q-ep N] [--noise-seed N]
                [--rounds T] [--devices K]
                [--seed N] [--eval-every E] [--metrics file.jsonl]
                [--backend native|pjrt] [--artifacts DIR] [--threads N]
                [--simd off|avx2|auto]
                [--staleness S] [--concurrent-devices N] [--per-device-opt]
                [--transport inproc|tcp] [--listen ADDR] [--devices-remote R]
                [--fading-sigma X] [--scenario SPEC] [--rpc-deadline-s X]
                [--retry-base-ms N] [--retry-cap-ms N] [--retry-deadline-s X]
                [--liveness-timeout-s X]
                [--checkpoint-every N] [--checkpoint-dir DIR]
                [--checkpoint-keep K] [--resume PATH]
  splitfc device --connect HOST:PORT[,HOST:PORT...] --device K --preset P
                [--scheme S] ...
                # device-side process for one remote device; preset, scheme,
                # seed and fleet flags must match the server's `train` run.
                # Extra --connect addresses are fallback parameter servers:
                # when the primary dies the device's reconnect loop rotates
                # through them and migrates mid-run (the adopting PS restores
                # the device's state from its loaded snapshot)
  splitfc experiment <fig1|fig3|fig4|fig5|table1|table2|table3|all>
                [--presets mnist,cifar,celeba] [--rounds T] [--devices K]
                [--threads N] ...
  splitfc codec-smoke [--r R]   # registry matrix: round-trip + one train
                                # step for every registered codec
  splitfc metrics-diff A.jsonl B.jsonl
                # compare two metrics streams on the deterministic step
                # fields (exit 1 on any divergence; wall-clock excluded)
  splitfc latency-calc [--capacity-bps 10e6 --batch 256 --dbar 8192
                --iters 100 --devices 100]
  splitfc inspect [--artifacts artifacts]
  splitfc ckpt inspect PATH [--json]
                # dump a checkpoint's self-describing header and section
                # table without loading any tensors (--json for scripts)
  splitfc help

SCHEMES (resolved through the codec registry; `codec-smoke` lists all):
  vanilla | splitfc | splitfc-ad | splitfc-rand | splitfc-det |
  splitfc-quant-only | splitfc-no-mean | splitfc-ad+{pq,eq,nq} |
  tops | randtops | tops+{pq,eq,nq} | fedlite
  Bracketed spec grammar configures a family directly, e.g.
    --scheme splitfc[ad,R=8,fwq]      (== --scheme splitfc --r 8)
    --scheme splitfc[det,R=4,fixedQ8] (Fig.-5 fixed-level ablation)
    --scheme splitfc[ad,R=8,fwq,ef]   (error-feedback session state)
    --scheme tops[theta=0.2,eq]       (RandTop-S + EasyQuant)
  Out-of-core codecs registered via compression::register_codec resolve
  the same way. --q-ep / --noise-seed pin the FWQ endpoint levels and the
  NoisyQuant noise stream for reproducible runs.

PERFORMANCE:
  --simd off|avx2|auto    kernel dispatch for the hot loops (matmul, column
                          stats, FWQ symbol pack/unpack). auto (default)
                          runtime-detects AVX2; off pins the portable scalar
                          kernels. The tables are bit-identical — metrics do
                          not change, only speed (env: SPLITFC_SIMD)

SCHEDULING:
  --staleness S           bounded-staleness window in rounds; 0 (default) is
                          the paper's strict sequential round-robin, S>0 lets
                          a device run up to S rounds ahead concurrently
  --concurrent-devices N  device-worker threads (0 = auto: 1 when S=0, one
                          per device otherwise)
  --per-device-opt        independent PS-held device ADAM moments per device

TRANSPORT:
  --transport inproc|tcp  message backend between devices and the PS:
                          bounded in-process channels (default) or
                          length-prefixed frames over TCP sockets; at
                          staleness 0 both produce byte-identical metrics
  --listen ADDR           PS listen address for tcp (default 127.0.0.1:0 =
                          ephemeral port, printed at startup)
  --devices-remote R      the last R devices join from separate `splitfc
                          device` processes instead of in-process threads
  --fading-sigma X        log-normal per-device link-capacity dispersion
                          (0 = every device at --capacity-bps)

SCENARIOS (seeded failure injection; same spec = same event timeline):
  --scenario SPEC         comma list of clauses in the codec-spec style, e.g.
                            seed=7,straggler[dev=2,slow=8x],
                            dropout[p=0.05,rejoin=2r],cut[dev=1,step=40],
                            wave[cohort=4,every=5r],depart[dev=3,round=4],
                            pscrash[round=2]
                          straggler  slow one device (dev=K) or a seeded
                                     random subset (p=P) by the slow= factor
                          dropout    per-round seeded dropout; affected
                                     devices sit out rejoin= rounds
                          cut        deterministic socket cut at the device's
                                     N-th step (step=) or wire send (send=,
                                     Hello is send #1); needs --transport tcp
                          wave       staggered joins in cohorts
                          depart     permanent departure before round T
                          pscrash    crash + restart the PS at the round=T
                                     checkpoint barrier (or the first barrier
                                     after send=N step replies); needs
                                     --transport tcp and --checkpoint-every;
                                     devices ride it out via their reconnect
                                     loops and the trajectory is unchanged
                          seed=N     scenario RNG (default: --seed); scenario
                                     draws never touch the training RNG
  --chaos-drop K:N[,K:N]  deprecated; same as --scenario cut[dev=K,send=N]
  --rpc-deadline-s X      per-request receive deadline on device connections
                          (0 = wait forever); expiry retries like an IO fault
  --retry-base-ms N       first backoff delay after a transport fault (10)
  --retry-cap-ms N        backoff delay ceiling (500); delays double per
                          attempt with seeded jitter in [0.5, 1.5)
  --retry-deadline-s X    give up after this much cumulative backoff (15)
  --liveness-timeout-s X  PS-side: a disconnected device silent this long is
                          marked departed and the run degrades gracefully to
                          the surviving cohort (0 = wait forever); set it
                          above --retry-deadline-s

CHECKPOINT & RESUME (byte-identical restart):
  --checkpoint-every N    snapshot the full run state every N rounds at the
                          round barrier (0 = off): server weights + ADAM
                          slots, per-device state incl. loader order and
                          codec sessions (error feedback), all RNG streams,
                          totals and metrics watermark
  --checkpoint-dir DIR    where snapshots land (default: checkpoints);
                          written atomically (tmp + rename)
  --checkpoint-keep K     retain the last K snapshots (default 3)
  --resume PATH           restart from a snapshot: validates the header
                          against the run config (named mismatch errors),
                          restores every state stream, appends to --metrics
                          after truncating post-snapshot records, and
                          continues at the next round — the metrics stream
                          is byte-identical to an uninterrupted run
";

pub fn main() {
    let args = Args::from_env();
    if args.has_flag("debug") {
        crate::util::logging::set_level(3);
    }
    // size the parallel runtime up front when --threads is given (configs
    // re-apply the same value through TrainConfig::apply_overrides); the
    // untouched default is one worker per core
    if args.get("threads").is_some() {
        crate::util::par::set_threads(args.get_usize("threads", 0));
    }
    // pin SIMD dispatch before any kernel runs; subcommands without a
    // TrainConfig (experiments, codec-smoke, benches) honor it too
    if let Some(v) = args.get("simd") {
        if let Err(e) = crate::util::simd::configure(v) {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
    let code = match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("device") => cmd_device(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("codec-smoke") => cmd_codec_smoke(&args),
        Some("metrics-diff") => cmd_metrics_diff(&args),
        Some("latency-calc") => cmd_latency(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("ckpt") => cmd_ckpt(&args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "mnist").to_string();
    let mut cfg = TrainConfig::for_preset(&preset);
    cfg.apply_overrides(args)?;
    println!("config: {}", cfg.to_json().to_string_compact());
    let mut tr = Trainer::new(cfg)?;
    if let Some(addr) = tr.listen_addr() {
        println!("transport: tcp, listening on {addr}");
        if tr.cfg.devices_remote > 0 {
            println!(
                "waiting for {} remote device(s): splitfc device --connect {addr} --device K ...",
                tr.cfg.devices_remote
            );
        }
    }
    let summary = tr.run()?;
    println!("summary: {}", summary.to_json().to_string_pretty());
    let rep = tr.link_report();
    println!(
        "link: up {} bits, down {} bits, modeled transfer time {:.2}s @ {} bps",
        rep.up_bits, rep.down_bits, rep.elapsed_s, tr.cfg.link_capacity_bps
    );
    println!(
        "model sync: up {} bits / {} frames, down {} bits / {} frames",
        rep.sync_up_bits, rep.sync_up_frames, rep.sync_down_bits, rep.sync_down_frames
    );
    Ok(())
}

/// Device-side entrypoint for one remote device: rebuild the fleet parts
/// from the same flags as the server's `train` run, dial it, and drive
/// this device through every round. `--connect` takes a comma-separated
/// ordered address list — the tail entries are fallback parameter servers
/// the device migrates to when the one it is on dies.
fn cmd_device(args: &Args) -> Result<()> {
    let addrs: Vec<String> = match args.get("connect") {
        Some(a) => a
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => Vec::new(),
    };
    if addrs.is_empty() {
        crate::bail!("device needs --connect HOST:PORT[,HOST:PORT...]");
    }
    let device = args.get_usize("device", usize::MAX);
    if device == usize::MAX {
        crate::bail!("device needs --device K (this process's device index)");
    }
    let preset = args.get_or("preset", "mnist").to_string();
    let mut cfg = TrainConfig::for_preset(&preset);
    cfg.apply_overrides(args)?;
    cfg.transport = TransportKind::Tcp;
    println!(
        "device {device} dialing {} ({})",
        addrs.join(", "),
        cfg.to_json().to_string_compact()
    );
    let rep = run_remote_device(&cfg, device, &addrs)?;
    println!(
        "device {device} done: up {} bits, down {} bits, modeled transfer time {:.2}s",
        rep.up_bits, rep.down_bits, rep.elapsed_s
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    experiments::run(id, args)
}

/// Registry matrix smoke: for every registered codec, one uplink/downlink
/// wire round trip plus one tiny train step — an unported or misregistered
/// codec fails fast here (CI runs this).
fn cmd_codec_smoke(args: &Args) -> Result<()> {
    use crate::compression::{registered_names, CodecParams, SigmaStats};
    use crate::ensure;
    use crate::tensor::{column_stats, normalized_sigma};
    use crate::testkit::hetero_matrix;
    use crate::util::Rng;

    let r = args.get_f64("r", 4.0);
    let names = registered_names();
    println!("{} registered codecs: {}", names.len(), names.join(", "));
    let (b, d) = (8usize, 64usize);
    let f = hetero_matrix(b, d, 17);
    let stats = SigmaStats::new(normalized_sigma(&column_stats(&f), 4));
    let g = crate::tensor::Matrix::from_fn(b, d, |ri, c| ((ri * 7 + c) % 5) as f32 * 0.02 - 0.04);
    for name in &names {
        let spec = crate::config::parse_scheme(name, r)?;
        let bpe = if name == "vanilla" { 32.0 } else { 1.0 };
        let up = CodecParams::new(b, d, bpe);
        let down = CodecParams::new(b, d, 2.0);

        // 1. wire round trip: decode-of-own-bytes must match the encoder's
        //    reported reconstructions exactly, both directions
        let mut codec = spec.build()?;
        let mut rng = Rng::new(99);
        let enc = codec.encode_uplink(&f, Some(&stats), &up, &mut rng)?;
        let dec = codec.decode_uplink(&enc.frame, &up)?;
        ensure!(dec.f_hat == enc.f_hat, "codec {name}: uplink wire decode mismatch");
        let dn = codec.encode_downlink(&g, &enc.mask, &down)?;
        let g_dec = codec.decode_downlink(&dn.frame, &enc.mask, &down)?;
        ensure!(g_dec == dn.g_hat, "codec {name}: downlink wire decode mismatch");

        // 2. one tiny train step through the full coordinator
        let mut cfg = TrainConfig::for_preset("tiny");
        cfg.devices = 1;
        cfg.rounds = 1;
        cfg.n_train = 64;
        cfg.n_test = 16;
        cfg.scheme = spec;
        cfg.up_bits_per_entry = bpe;
        cfg.down_bits_per_entry = 32.0;
        let mut tr = Trainer::new(cfg)?;
        let rec = tr.step(1, 0)?;
        ensure!(rec.loss.is_finite(), "codec {name}: non-finite loss");
        ensure!(rec.up_bits > 0, "codec {name}: empty uplink frame");
        println!(
            "  {name:<20} ok  (encode {} bits, step loss {:.4})",
            enc.frame.payload_bits, rec.loss
        );
    }
    println!("codec-smoke OK ({} codecs)", names.len());
    Ok(())
}

/// Compare two metrics JSONL streams on the deterministic per-step fields
/// (wall-clock fields excluded): the determinism contract for scenarios is
/// "same `--scenario`, same seed, same fleet ⇒ identical streams", and CI
/// enforces it with this command.
fn cmd_metrics_diff(args: &Args) -> Result<()> {
    use crate::util::Json;
    const KEYS: [&str; 9] = [
        "t", "k", "g", "loss", "train_acc", "up_bits", "down_bits", "up_nominal",
        "down_nominal",
    ];
    let (a, b) = match (args.positional.get(1), args.positional.get(2)) {
        (Some(a), Some(b)) => (a.clone(), b.clone()),
        _ => crate::bail!("metrics-diff wants two JSONL paths"),
    };
    let load = |path: &str| -> Result<Vec<String>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("cannot read {path:?}: {e}"))?;
        let mut rows = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .map_err(|e| crate::err!("{path}:{}: bad JSON: {e}", i + 1))?;
            // summary/config lines lack step keys; only step records count
            if j.get("g").is_none() {
                continue;
            }
            let mut fields = Vec::with_capacity(KEYS.len());
            for k in KEYS {
                let v = j
                    .get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| crate::err!("{path}:{}: missing field {k:?}", i + 1))?;
                fields.push(format!("{k}={v:?}"));
            }
            rows.push(fields.join(" "));
        }
        Ok(rows)
    };
    let (ra, rb) = (load(&a)?, load(&b)?);
    crate::ensure!(
        ra.len() == rb.len(),
        "step counts differ: {} has {} steps, {} has {}",
        a,
        ra.len(),
        b,
        rb.len()
    );
    for (i, (x, y)) in ra.iter().zip(rb.iter()).enumerate() {
        crate::ensure!(
            x == y,
            "step {} diverges:\n  {a}: {x}\n  {b}: {y}",
            i + 1
        );
    }
    println!("metrics-diff OK: {} steps identical on {} fields", ra.len(), KEYS.len());
    Ok(())
}

fn cmd_latency(args: &Args) -> Result<()> {
    // the paper's intro example by default: ~1.34e5 seconds
    let cap = args.get_f64("capacity-bps", 10e6);
    let batch = args.get_usize("batch", 256);
    let dbar = args.get_usize("dbar", 8192);
    let iters = args.get_usize("iters", 100);
    let devices = args.get_usize("devices", 100);
    let t = vanilla_sl_transfer_time_s(cap, batch, dbar, iters, devices);
    println!(
        "vanilla SL transfer time: {t:.3e} s  (capacity {cap:.3e} bps, B={batch}, \
         Dbar={dbar}, T={iters}, K={devices})"
    );
    for ratio in [160.0, 240.0, 320.0] {
        println!("  at {ratio:>4}x compression: {:.3e} s", t / ratio);
    }
    Ok(())
}

/// `splitfc ckpt inspect PATH`: print a checkpoint's self-describing
/// envelope — magic, format version, codec identity, fleet shape, the
/// per-section length/CRC table — without decoding a single tensor.
/// Corrupt, truncated and future-format files fail with typed errors.
fn cmd_ckpt(args: &Args) -> Result<()> {
    let action = args.positional.get(1).map(|s| s.as_str());
    let path = match (action, args.positional.get(2)) {
        (Some("inspect"), Some(p)) => std::path::Path::new(p.as_str()),
        _ => crate::bail!("usage: splitfc ckpt inspect PATH [--json]"),
    };
    let info = crate::checkpoint::inspect(path)?;
    let h = &info.header;
    if args.has_flag("json") {
        use crate::util::Json;
        let sections = info
            .sections
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(s.name.clone())),
                    ("len", Json::num(s.len as f64)),
                    ("crc", Json::str(format!("{:08x}", s.crc))),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("path", Json::str(path.display().to_string())),
            ("file_len", Json::num(info.file_len as f64)),
            ("format", Json::num(h.format as f64)),
            ("codec_id", Json::num(h.codec_id as f64)),
            ("codec_version", Json::num(h.codec_version as f64)),
            ("scheme", Json::str(h.scheme.clone())),
            ("preset", Json::str(h.preset.clone())),
            ("devices", Json::num(h.devices as f64)),
            ("rounds", Json::num(h.rounds as f64)),
            ("round", Json::num(h.round as f64)),
            ("seed", Json::num(h.seed as f64)),
            ("fingerprint", Json::str(format!("{:016x}", h.fingerprint))),
            ("scenario", Json::str(h.scenario.clone())),
            ("sections", Json::Arr(sections)),
        ]);
        println!("{}", j.to_string_pretty());
        return Ok(());
    }
    println!("checkpoint {} ({} bytes)", path.display(), info.file_len);
    println!("  format:      v{}", h.format);
    println!("  codec:       id {} v{} ({})", h.codec_id, h.codec_version, h.scheme);
    println!("  preset:      {}", h.preset);
    println!("  fleet:       {} device(s), {} round(s)", h.devices, h.rounds);
    println!("  round:       {} (resume starts at {})", h.round, h.round + 1);
    println!("  seed:        {}", h.seed);
    println!("  fingerprint: {:016x}", h.fingerprint);
    println!(
        "  scenario:    {}",
        if h.scenario.is_empty() { "(calm)" } else { &h.scenario }
    );
    println!("  sections:");
    for s in &info.sections {
        println!("    {:<10} {:>10} bytes  crc32 {:08x}", s.name, s.len, s.crc);
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let m = crate::runtime::Manifest::load(&dir)?;
    println!("manifest format {} — {} presets", m.format, m.presets.len());
    for (name, p) in &m.presets {
        println!(
            "  {name}: B={} Dbar={} H={} classes={} N_d={} N_s={} entries={}",
            p.batch,
            p.dbar,
            p.num_channels,
            p.classes,
            p.nd_params,
            p.ns_params,
            p.entries.len()
        );
        for (ename, e) in &p.entries {
            let sz = std::fs::metadata(dir.join(&e.file)).map(|m| m.len()).unwrap_or(0);
            println!(
                "      {ename}: {} in -> {} out ({} bytes HLO)",
                e.num_inputs, e.num_outputs, sz
            );
        }
    }
    Ok(())
}
