//! `splitfc` CLI — leader entrypoint. See `splitfc help`.

fn main() {
    splitfc::coordinator::cli::main();
}
