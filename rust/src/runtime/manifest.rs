//! Artifact manifest (`artifacts/manifest.json`) parsing.

use std::collections::BTreeMap;
use std::path::Path;

use crate::model::PresetInfo;
use crate::util::error::{Context, Result};
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: usize,
    pub presets: BTreeMap<String, PresetInfo>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("{path:?} not found — run `make artifacts` first")
        })?;
        let j = Json::parse(&text).map_err(|e| crate::err!("{e}"))?;
        let mut presets = BTreeMap::new();
        for (name, pj) in j.req("presets").as_obj().context("presets")? {
            presets.insert(name.clone(), PresetInfo::from_json(name, pj));
        }
        Ok(Manifest { format: j.req("format").as_usize().unwrap_or(1), presets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The real manifest written by `make artifacts` — validates the full
    /// python->rust contract when artifacts exist.
    #[test]
    fn loads_real_manifest_when_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.presets.contains_key("tiny"));
        let tiny = &m.presets["tiny"];
        assert_eq!(tiny.entries.len(), 5);
        for e in tiny.entries.values() {
            assert!(dir.join(&e.file).exists());
        }
        // the paper-exact mnist preset
        if let Some(mnist) = m.presets.get("mnist") {
            assert_eq!(mnist.nd_params, 4800);
            assert_eq!(mnist.ns_params, 148874);
            assert_eq!(mnist.dbar, 1152);
        }
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent-dir-xyz")).is_err());
    }
}
