//! PJRT runtime bridge: load the AOT HLO-text artifacts and execute them on
//! the hot path. Pattern follows /opt/xla-example/load_hlo — HLO *text* is
//! the interchange format (xla_extension 0.5.1 rejects jax≥0.5 protos).

pub mod exec;
pub mod manifest;

pub use exec::{literal_to_vec_f32, matrix_to_literal, vec_to_literal};
pub use manifest::Manifest;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::{ParamSet, PresetInfo};
use crate::model::params::f32_from_le_bytes;

pub struct Module {
    exe: xla::PjRtLoadedExecutable,
    pub num_inputs: usize,
    pub num_outputs: usize,
}

/// A loaded preset: PJRT client + one compiled executable per entry point.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub preset: PresetInfo,
    pub dir: PathBuf,
    modules: BTreeMap<String, Module>,
}

impl Runtime {
    /// Load `artifacts/<preset>/*` and compile every entry point.
    pub fn load(artifacts_dir: &Path, preset_name: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let preset = manifest
            .presets
            .get(preset_name)
            .with_context(|| format!("preset {preset_name:?} not in manifest"))?
            .clone();
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut modules = BTreeMap::new();
        for (name, entry) in &preset.entries {
            let path = artifacts_dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            modules.insert(
                name.clone(),
                Module { exe, num_inputs: entry.num_inputs, num_outputs: entry.num_outputs },
            );
        }
        Ok(Runtime { client, preset, dir: artifacts_dir.to_path_buf(), modules })
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.modules.contains_key(name)
    }

    /// Execute an entry point. Inputs must match the manifest arity; outputs
    /// are the flattened tuple elements (aot.py lowers with return_tuple).
    pub fn exec(&self, entry: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let m = self
            .modules
            .get(entry)
            .with_context(|| format!("unknown entry {entry:?}"))?;
        anyhow::ensure!(
            inputs.len() == m.num_inputs,
            "entry {entry}: got {} inputs, manifest says {}",
            inputs.len(),
            m.num_inputs
        );
        let result = m.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(
            outs.len() == m.num_outputs,
            "entry {entry}: got {} outputs, manifest says {}",
            outs.len(),
            m.num_outputs
        );
        Ok(outs)
    }

    /// Load the initial parameters (device-side, server-side) from params.bin.
    pub fn load_params(&self) -> Result<(ParamSet, ParamSet)> {
        let blob = std::fs::read(self.dir.join(&self.preset.params_file))?;
        let floats = f32_from_le_bytes(&blob);
        anyhow::ensure!(
            floats.len() == self.preset.nd_params + self.preset.ns_params,
            "params.bin size mismatch"
        );
        let (d, s) = floats.split_at(self.preset.nd_params);
        Ok((
            ParamSet::new(self.preset.device_params.clone(), d.to_vec()),
            ParamSet::new(self.preset.server_params.clone(), s.to_vec()),
        ))
    }
}
