//! Execution backends for the split model.
//!
//! The coordinator (Algorithm 1) drives the model exclusively through the
//! [`Backend`] trait — the four hot-path entry points of the split protocol
//! plus parameter init and evaluation. Two implementations:
//!
//! * [`native::NativeBackend`] (default): pure-Rust split MLP presets over
//!   `tensor::Matrix` (matmul / ReLU / softmax-CE forward+backward and the
//!   σ-statistics kernel of eq. 10). Zero external dependencies — this is
//!   what CI and the offline build exercise.
//! * [`pjrt::PjrtBackend`] (`--features pjrt`): loads the AOT HLO-text
//!   artifacts produced by `python/compile` and executes them through the
//!   PJRT CPU client (HLO *text* is the interchange format — xla_extension
//!   0.5.1 rejects jax≥0.5 protos).

pub mod manifest;
pub mod native;

#[cfg(feature = "pjrt")]
pub mod exec;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use manifest::Manifest;
pub use native::NativeBackend;

#[cfg(feature = "pjrt")]
pub use exec::{literal_to_matrix, literal_to_vec_f32, matrix_to_literal, vec_to_literal};
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtBackend, Runtime};

use crate::model::{ParamSet, PresetInfo};
use crate::tensor::Matrix;
use crate::util::error::Result;

/// Which execution backend a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust split MLP presets (offline default).
    #[default]
    Native,
    /// PJRT execution of AOT HLO artifacts (requires `--features pjrt`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(crate::err!("unknown backend {other:?} (native|pjrt)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Everything the parameter server produces in one forward/backward pass
/// (paper eqs. 4-5): scalar loss and batch-correct count, the flat gradient
/// of the server-side parameters, and the intermediate gradient G = ∇_F̂ h
/// that travels back over the downlink.
#[derive(Debug, Clone)]
pub struct ServerOutput {
    pub loss: f32,
    pub correct: f32,
    pub grad_ws: Vec<f32>,
    /// B × D̄ gradient w.r.t. the (reconstructed) feature matrix.
    pub g: Matrix,
}

/// One execution backend: the five model entry points of the split protocol.
///
/// `x` is a flat NCHW batch (`batch * C*H*W` floats), `y` a flat one-hot
/// label matrix (`batch * classes`); parameter sets use the layout declared
/// by [`PresetInfo::device_params`] / [`PresetInfo::server_params`].
///
/// Every entry point takes `&self` and the trait requires `Send + Sync`:
/// the concurrent coordinator shares one backend across all device-worker
/// threads (parameters are always passed in, so implementations hold no
/// per-call mutable state). An implementation wrapping a non-thread-safe
/// runtime handle must add its own interior locking.
pub trait Backend: Send + Sync {
    /// Static description of the loaded preset (shapes, param layout).
    fn preset(&self) -> &PresetInfo;

    /// Initial (device-side, server-side) parameters. Deterministic per
    /// preset so runs are reproducible from the config seed alone.
    fn init_params(&self) -> Result<(ParamSet, ParamSet)>;

    /// Device sub-model forward: x → F (B × D̄, eq. 3).
    fn device_fwd(&self, wd: &ParamSet, x: &[f32]) -> Result<Matrix>;

    /// Per-column σ of the channel-normalized features (eq. 10) — the
    /// statistics kernel FWDP consumes.
    fn feature_stats(&self, f: &Matrix) -> Result<Vec<f32>>;

    /// Server sub-model forward + backward on the reconstructed features
    /// (eqs. 4-5): loss, correct count, ∇w_s, and G = ∇_F̂ h.
    fn server_fwd_bwd(&self, ws: &ParamSet, f_hat: &Matrix, y: &[f32]) -> Result<ServerOutput>;

    /// Device sub-model backward from the (decoded, chain-rule-scaled)
    /// gradient Ĝ: returns the flat ∇w_d.
    fn device_bwd(&self, wd: &ParamSet, x: &[f32], g_hat: &Matrix) -> Result<Vec<f32>>;

    /// Full-model forward for evaluation: logits (batch * classes).
    fn eval_logits(&self, wd: &ParamSet, ws: &ParamSet, x: &[f32]) -> Result<Vec<f32>>;

    fn name(&self) -> &'static str;
}

/// Build the backend a config asks for. `artifacts_dir` is only consulted by
/// the PJRT path; the native backend is self-contained.
pub fn create_backend(
    kind: BackendKind,
    artifacts_dir: &str,
    preset: &str,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => Ok(Box::new(NativeBackend::for_preset(preset)?)),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Box::new(PjrtBackend::load(
            std::path::Path::new(artifacts_dir),
            preset,
        )?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => {
            let _ = artifacts_dir;
            Err(crate::err!(
                "backend 'pjrt' requires building with `--features pjrt` \
                 (this binary was built with the native backend only)"
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parse_and_name() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default().name(), "native");
    }

    #[test]
    fn create_native_backend_for_all_presets() {
        for preset in ["tiny", "mnist", "cifar", "celeba"] {
            let b = create_backend(BackendKind::Native, "artifacts", preset).unwrap();
            assert_eq!(b.preset().name, preset);
            assert_eq!(b.name(), "native");
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_errors_without_feature() {
        // (no unwrap_err: Box<dyn Backend> has no Debug impl)
        match create_backend(BackendKind::Pjrt, "artifacts", "tiny") {
            Err(e) => assert!(e.to_string().contains("pjrt")),
            Ok(_) => panic!("expected an error without the pjrt feature"),
        }
    }
}
