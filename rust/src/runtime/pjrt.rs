//! PJRT runtime bridge: load the AOT HLO-text artifacts and execute them on
//! the hot path. Pattern follows /opt/xla-example/load_hlo — HLO *text* is
//! the interchange format (xla_extension 0.5.1 rejects jax≥0.5 protos).
//!
//! Compiled only under `--features pjrt`. Offline builds link the
//! API-compatible stub in `third_party/xla-stub`; swap in a real xla-rs
//! checkout to actually execute (README.md).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::ensure;
use crate::model::{ParamSet, PresetInfo};
use crate::model::params::f32_from_le_bytes;
use crate::runtime::exec::{literal_to_vec_f32, matrix_to_literal, vec_to_literal};
use crate::runtime::manifest::Manifest;
use crate::runtime::{Backend, ServerOutput};
use crate::tensor::Matrix;
use crate::util::error::{Context, Result};

pub struct Module {
    exe: xla::PjRtLoadedExecutable,
    pub num_inputs: usize,
    pub num_outputs: usize,
}

/// A loaded preset: PJRT client + one compiled executable per entry point.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub preset: PresetInfo,
    pub dir: PathBuf,
    modules: BTreeMap<String, Module>,
}

impl Runtime {
    /// Load `artifacts/<preset>/*` and compile every entry point.
    pub fn load(artifacts_dir: &Path, preset_name: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let preset = manifest
            .presets
            .get(preset_name)
            .with_context(|| format!("preset {preset_name:?} not in manifest"))?
            .clone();
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut modules = BTreeMap::new();
        for (name, entry) in &preset.entries {
            let path = artifacts_dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            modules.insert(
                name.clone(),
                Module { exe, num_inputs: entry.num_inputs, num_outputs: entry.num_outputs },
            );
        }
        Ok(Runtime { client, preset, dir: artifacts_dir.to_path_buf(), modules })
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.modules.contains_key(name)
    }

    /// Execute an entry point. Inputs must match the manifest arity; outputs
    /// are the flattened tuple elements (aot.py lowers with return_tuple).
    pub fn exec(&self, entry: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let m = self
            .modules
            .get(entry)
            .with_context(|| format!("unknown entry {entry:?}"))?;
        ensure!(
            inputs.len() == m.num_inputs,
            "entry {entry}: got {} inputs, manifest says {}",
            inputs.len(),
            m.num_inputs
        );
        let result = m
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {entry}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("{entry}: device->host transfer"))?;
        let outs = lit
            .to_tuple()
            .with_context(|| format!("{entry}: untuple outputs"))?;
        ensure!(
            outs.len() == m.num_outputs,
            "entry {entry}: got {} outputs, manifest says {}",
            outs.len(),
            m.num_outputs
        );
        Ok(outs)
    }

    /// Load the initial parameters (device-side, server-side) from params.bin.
    pub fn load_params(&self) -> Result<(ParamSet, ParamSet)> {
        let blob = std::fs::read(self.dir.join(&self.preset.params_file))?;
        let floats = f32_from_le_bytes(&blob);
        ensure!(
            floats.len() == self.preset.nd_params + self.preset.ns_params,
            "params.bin size mismatch"
        );
        let (d, s) = floats.split_at(self.preset.nd_params);
        Ok((
            ParamSet::new(self.preset.device_params.clone(), d.to_vec()),
            ParamSet::new(self.preset.server_params.clone(), s.to_vec()),
        ))
    }
}

/// [`Backend`] implementation over a loaded PJRT [`Runtime`]: each protocol
/// entry point maps to one compiled HLO artifact. `Backend` requires
/// `Send + Sync`; the PJRT CPU client and loaded executables are thread-safe
/// handles (executions are independent), and the offline stub types are
/// plain zero-sized markers, so the impl is shareable across device-worker
/// threads without extra locking.
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    pub fn load(artifacts_dir: &Path, preset: &str) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: Runtime::load(artifacts_dir, preset)? })
    }

    /// Direct access to the underlying runtime (artifact tooling, tests).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn param_literals(set: &ParamSet) -> Result<Vec<xla::Literal>> {
        (0..set.n_tensors())
            .map(|i| vec_to_literal(set.tensor(i), &set.specs[i].shape))
            .collect()
    }

    fn input_literal(&self, x: &[f32]) -> Result<xla::Literal> {
        let p = &self.rt.preset;
        vec_to_literal(x, &[p.batch, p.in_shape[0], p.in_shape[1], p.in_shape[2]])
    }
}

impl Backend for PjrtBackend {
    fn preset(&self) -> &PresetInfo {
        &self.rt.preset
    }

    fn init_params(&self) -> Result<(ParamSet, ParamSet)> {
        self.rt.load_params()
    }

    fn device_fwd(&self, wd: &ParamSet, x: &[f32]) -> Result<Matrix> {
        let mut inputs = Self::param_literals(wd)?;
        inputs.push(self.input_literal(x)?);
        let outs = self.rt.exec("device_fwd", &inputs)?;
        let p = &self.rt.preset;
        Ok(Matrix::from_vec(p.batch, p.dbar, literal_to_vec_f32(&outs[0])?))
    }

    fn feature_stats(&self, f: &Matrix) -> Result<Vec<f32>> {
        // the L1 Pallas kernel artifact: outputs (min, max, mean, σ_norm)
        let outs = self.rt.exec("feature_stats", &[matrix_to_literal(f)?])?;
        literal_to_vec_f32(&outs[3])
    }

    fn server_fwd_bwd(&self, ws: &ParamSet, f_hat: &Matrix, y: &[f32]) -> Result<ServerOutput> {
        let p = self.rt.preset.clone();
        let mut inputs = Self::param_literals(ws)?;
        inputs.push(matrix_to_literal(f_hat)?);
        inputs.push(vec_to_literal(y, &[p.batch, p.classes])?);
        let outs = self.rt.exec("server_fwd_bwd", &inputs)?;
        let loss = literal_to_vec_f32(&outs[0])?[0];
        let correct = literal_to_vec_f32(&outs[1])?[0];
        let ns = ws.n_tensors();
        let mut grad_ws = Vec::with_capacity(ws.n_params());
        for i in 0..ns {
            grad_ws.extend(literal_to_vec_f32(&outs[2 + i])?);
        }
        let g = Matrix::from_vec(p.batch, p.dbar, literal_to_vec_f32(&outs[2 + ns])?);
        Ok(ServerOutput { loss, correct, grad_ws, g })
    }

    fn device_bwd(&self, wd: &ParamSet, x: &[f32], g_hat: &Matrix) -> Result<Vec<f32>> {
        let mut inputs = Self::param_literals(wd)?;
        inputs.push(self.input_literal(x)?);
        inputs.push(matrix_to_literal(g_hat)?);
        let outs = self.rt.exec("device_bwd", &inputs)?;
        let mut grad = Vec::with_capacity(wd.n_params());
        for o in &outs {
            grad.extend(literal_to_vec_f32(o)?);
        }
        Ok(grad)
    }

    fn eval_logits(&self, wd: &ParamSet, ws: &ParamSet, x: &[f32]) -> Result<Vec<f32>> {
        let mut inputs = Self::param_literals(wd)?;
        inputs.extend(Self::param_literals(ws)?);
        inputs.push(self.input_literal(x)?);
        let outs = self.rt.exec("eval_fwd", &inputs)?;
        literal_to_vec_f32(&outs[0])
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
