//! Literal ⇄ host-matrix conversion helpers (PJRT path only).

use crate::ensure;
use crate::tensor::Matrix;
use crate::util::error::{Context, Result};

/// Build an f32 literal of the given logical shape from a flat slice.
pub fn vec_to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    ensure!(numel == data.len(), "shape/data mismatch: {shape:?} vs {}", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).context("literal reshape")
}

pub fn matrix_to_literal(m: &Matrix) -> Result<xla::Literal> {
    vec_to_literal(&m.data, &[m.rows, m.cols])
}

pub fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to_vec<f32>")
}

pub fn literal_to_matrix(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v = literal_to_vec_f32(lit)?;
    ensure!(v.len() == rows * cols, "literal size mismatch");
    Ok(Matrix::from_vec(rows, cols, v))
}

pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = literal_to_vec_f32(lit)?;
    ensure!(!v.is_empty(), "empty literal");
    Ok(v[0])
}
