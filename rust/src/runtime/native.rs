//! Pure-Rust execution backend: split MLP presets over `tensor::Matrix`.
//!
//! The split model mirrors the paper's device/server cut (Sec. III):
//!
//! * device side  g(w_d, x):  flatten → W1 (din×D̄) + b1 → ReLU → F (B×D̄)
//! * server side  h(w_s, F̂):  W2 (D̄×H) + b2 → ReLU → W3 (H×classes) + b3
//!                             → softmax cross-entropy
//!
//! The intermediate features are a ReLU output (non-negative, per-column
//! dispersion varies with the input statistics), which is exactly the regime
//! FWDP/FWQ exploit (Fig. 1). The σ-statistics kernel (eq. 10) is computed
//! by the same host oracle the tests use against the Pallas artifact.
//!
//! Presets are CPU-feasible stand-ins for the paper's scenarios — the `tiny`
//! preset matches the PJRT `tiny` artifact shapes so both backends are
//! interchangeable in the coordinator; mnist/cifar/celeba keep the paper's
//! input shapes and cut-layer widths at laptop-scale hidden sizes.

use std::collections::BTreeMap;

use crate::ensure;
use crate::model::{ParamSet, ParamSpec, PresetInfo};
use crate::runtime::{Backend, ServerOutput};
use crate::tensor::{column_stats, normalized_sigma, Matrix};
use crate::util::error::Result;
use crate::util::Rng;

pub struct NativeBackend {
    preset: PresetInfo,
    init_seed: u64,
}

/// (batch, in_shape, dbar, chan_size, hidden, classes, seed) per preset.
type PresetDims = (usize, [usize; 3], usize, usize, usize, usize, u64);

fn preset_dims(name: &str) -> Result<PresetDims> {
    Ok(match name {
        "tiny" => (8, [1, 8, 8], 32, 4, 32, 4, 0x7117),
        "mnist" => (32, [1, 28, 28], 1152, 36, 128, 10, 0x0717),
        "cifar" => (32, [3, 32, 32], 512, 32, 128, 100, 0xC1FA),
        "celeba" => (32, [3, 32, 32], 512, 32, 64, 2, 0xCE1B),
        other => {
            return Err(crate::err!(
                "native backend has no preset {other:?} (tiny|mnist|cifar|celeba)"
            ))
        }
    })
}

impl NativeBackend {
    pub fn for_preset(name: &str) -> Result<NativeBackend> {
        let (batch, in_shape, dbar, chan_size, hidden, classes, seed) = preset_dims(name)?;
        let din: usize = in_shape.iter().product();
        let device_params = vec![
            ParamSpec { name: "w1".into(), shape: vec![din, dbar] },
            ParamSpec { name: "b1".into(), shape: vec![dbar] },
        ];
        let server_params = vec![
            ParamSpec { name: "w2".into(), shape: vec![dbar, hidden] },
            ParamSpec { name: "b2".into(), shape: vec![hidden] },
            ParamSpec { name: "w3".into(), shape: vec![hidden, classes] },
            ParamSpec { name: "b3".into(), shape: vec![classes] },
        ];
        let nd_params: usize = device_params.iter().map(|s| s.numel()).sum();
        let ns_params: usize = server_params.iter().map(|s| s.numel()).sum();
        let preset = PresetInfo {
            name: name.to_string(),
            batch,
            dbar,
            num_channels: dbar / chan_size,
            chan_size,
            classes,
            in_shape: in_shape.to_vec(),
            nd_params,
            ns_params,
            device_params,
            server_params,
            params_file: String::new(),
            entries: BTreeMap::new(),
        };
        Ok(NativeBackend { preset, init_seed: seed })
    }

    fn batch(&self) -> usize {
        self.preset.batch
    }

    fn din(&self) -> usize {
        self.preset.sample_dim()
    }

    /// Materialize parameter tensor `i` of `set` as a matrix (2-D specs).
    fn weight(set: &ParamSet, i: usize) -> Matrix {
        let shape = &set.specs[i].shape;
        debug_assert_eq!(shape.len(), 2);
        Matrix::from_vec(shape[0], shape[1], set.tensor(i).to_vec())
    }

    fn input_matrix(&self, x: &[f32]) -> Result<Matrix> {
        ensure!(
            x.len() == self.batch() * self.din(),
            "input batch has {} floats, expected {}x{}",
            x.len(),
            self.batch(),
            self.din()
        );
        Ok(Matrix::from_vec(self.batch(), self.din(), x.to_vec()))
    }

    /// Device pre-activation z1 = x·W1 + b1 (B × D̄).
    fn device_pre(&self, wd: &ParamSet, xm: &Matrix) -> Matrix {
        let w1 = Self::weight(wd, 0);
        let mut z1 = xm.matmul(&w1);
        z1.add_row_vec(wd.tensor(1));
        z1
    }

    /// Server forward: (z2 pre-activation, hidden activation, logits).
    /// Takes the already-materialized weight matrices so the backward pass
    /// can reuse them instead of copying the tensors again.
    fn server_forward(ws: &ParamSet, w2: &Matrix, w3: &Matrix, f: &Matrix) -> (Matrix, Matrix, Matrix) {
        let mut z2 = f.matmul(w2);
        z2.add_row_vec(ws.tensor(1));
        let mut h = z2.clone();
        h.relu_inplace();
        let mut logits = h.matmul(w3);
        logits.add_row_vec(ws.tensor(3));
        (z2, h, logits)
    }
}

/// Softmax cross-entropy over one-hot targets: (mean loss, correct count,
/// ∂loss/∂logits already scaled by 1/B). Log-sum-exp is accumulated in f64
/// for a numerically quiet loss.
fn softmax_xent(logits: &Matrix, y: &[f32]) -> (f32, f32, Matrix) {
    let (b, c) = (logits.rows, logits.cols);
    assert_eq!(y.len(), b * c, "one-hot target shape");
    let mut dlogits = Matrix::zeros(b, c);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for r in 0..b {
        let row = logits.row(r);
        let yrow = &y[r * c..(r + 1) * c];
        let label = yrow
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        correct += (pred == label) as usize;
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let sum: f64 = row.iter().map(|&v| ((v as f64) - mx).exp()).sum();
        let lse = mx + sum.ln();
        loss += lse - row[label] as f64;
        let drow = &mut dlogits.data[r * c..(r + 1) * c];
        for j in 0..c {
            let p = ((row[j] as f64) - lse).exp() as f32;
            drow[j] = (p - yrow[j]) / b as f32;
        }
    }
    ((loss / b as f64) as f32, correct as f32, dlogits)
}

impl Backend for NativeBackend {
    fn preset(&self) -> &PresetInfo {
        &self.preset
    }

    fn init_params(&self) -> Result<(ParamSet, ParamSet)> {
        // He-normal weights, zero biases; seeded per preset (the native
        // analogue of the fixed params.bin the AOT bundle ships).
        let mut rng = Rng::new(self.init_seed);
        let mut init = |specs: &[ParamSpec]| -> Vec<f32> {
            let mut data = Vec::with_capacity(specs.iter().map(|s| s.numel()).sum());
            for s in specs {
                if s.shape.len() == 2 {
                    let std = (2.0 / s.shape[0] as f32).sqrt();
                    data.extend((0..s.numel()).map(|_| rng.normal_f32(0.0, std)));
                } else {
                    data.resize(data.len() + s.numel(), 0.0);
                }
            }
            data
        };
        let d = init(&self.preset.device_params);
        let s = init(&self.preset.server_params);
        Ok((
            ParamSet::new(self.preset.device_params.clone(), d),
            ParamSet::new(self.preset.server_params.clone(), s),
        ))
    }

    fn device_fwd(&self, wd: &ParamSet, x: &[f32]) -> Result<Matrix> {
        let xm = self.input_matrix(x)?;
        let mut f = self.device_pre(wd, &xm);
        f.relu_inplace();
        Ok(f)
    }

    fn feature_stats(&self, f: &Matrix) -> Result<Vec<f32>> {
        ensure!(
            f.cols == self.preset.dbar,
            "feature_stats: {} cols vs D̄={}",
            f.cols,
            self.preset.dbar
        );
        Ok(normalized_sigma(&column_stats(f), self.preset.chan_size))
    }

    fn server_fwd_bwd(&self, ws: &ParamSet, f_hat: &Matrix, y: &[f32]) -> Result<ServerOutput> {
        ensure!(
            (f_hat.rows, f_hat.cols) == (self.batch(), self.preset.dbar),
            "server_fwd_bwd: F̂ is {}x{}, expected {}x{}",
            f_hat.rows,
            f_hat.cols,
            self.batch(),
            self.preset.dbar
        );
        let w2 = Self::weight(ws, 0);
        let w3 = Self::weight(ws, 2);
        let (z2, h, logits) = Self::server_forward(ws, &w2, &w3, f_hat);
        let (loss, correct, dlogits) = softmax_xent(&logits, y);

        let grad_w3 = h.matmul_tn(&dlogits);
        let grad_b3 = dlogits.col_sums();
        let mut dh = dlogits.matmul_nt(&w3);
        dh.relu_mask(&z2);
        let grad_w2 = f_hat.matmul_tn(&dh);
        let grad_b2 = dh.col_sums();
        let g = dh.matmul_nt(&w2);

        let grad_ws = ParamSet::concat(&[grad_w2.data, grad_b2, grad_w3.data, grad_b3]);
        debug_assert_eq!(grad_ws.len(), self.preset.ns_params);
        Ok(ServerOutput { loss, correct, grad_ws, g })
    }

    fn device_bwd(&self, wd: &ParamSet, x: &[f32], g_hat: &Matrix) -> Result<Vec<f32>> {
        ensure!(
            (g_hat.rows, g_hat.cols) == (self.batch(), self.preset.dbar),
            "device_bwd: Ĝ is {}x{}, expected {}x{}",
            g_hat.rows,
            g_hat.cols,
            self.batch(),
            self.preset.dbar
        );
        let xm = self.input_matrix(x)?;
        let z1 = self.device_pre(wd, &xm);
        let mut dz = g_hat.clone();
        dz.relu_mask(&z1);
        let grad_w1 = xm.matmul_tn(&dz);
        let grad_b1 = dz.col_sums();
        let grad = ParamSet::concat(&[grad_w1.data, grad_b1]);
        debug_assert_eq!(grad.len(), self.preset.nd_params);
        Ok(grad)
    }

    fn eval_logits(&self, wd: &ParamSet, ws: &ParamSet, x: &[f32]) -> Result<Vec<f32>> {
        let f = self.device_fwd(wd, x)?;
        let w2 = Self::weight(ws, 0);
        let w3 = Self::weight(ws, 2);
        let (_, _, logits) = Self::server_forward(ws, &w2, &w3, &f);
        Ok(logits.data)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small backend with non-preset dims for gradient checks.
    fn small() -> NativeBackend {
        let device_params = vec![
            ParamSpec { name: "w1".into(), shape: vec![6, 4] },
            ParamSpec { name: "b1".into(), shape: vec![4] },
        ];
        let server_params = vec![
            ParamSpec { name: "w2".into(), shape: vec![4, 3] },
            ParamSpec { name: "b2".into(), shape: vec![3] },
            ParamSpec { name: "w3".into(), shape: vec![3, 2] },
            ParamSpec { name: "b3".into(), shape: vec![2] },
        ];
        let nd: usize = device_params.iter().map(|s| s.numel()).sum();
        let ns: usize = server_params.iter().map(|s| s.numel()).sum();
        NativeBackend {
            preset: PresetInfo {
                name: "small".into(),
                batch: 3,
                dbar: 4,
                num_channels: 2,
                chan_size: 2,
                classes: 2,
                in_shape: vec![1, 2, 3],
                nd_params: nd,
                ns_params: ns,
                device_params,
                server_params,
                params_file: String::new(),
                entries: BTreeMap::new(),
            },
            init_seed: 99,
        }
    }

    fn batch_xy(be: &NativeBackend, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let p = be.preset();
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..p.batch * p.sample_dim())
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let mut y = vec![0.0f32; p.batch * p.classes];
        for b in 0..p.batch {
            y[b * p.classes + rng.gen_range(p.classes)] = 1.0;
        }
        (x, y)
    }

    /// Full split-model loss at the given parameters (vanilla path).
    fn loss_at(be: &NativeBackend, wd: &ParamSet, ws: &ParamSet, x: &[f32], y: &[f32]) -> f64 {
        let f = be.device_fwd(wd, x).unwrap();
        be.server_fwd_bwd(ws, &f, y).unwrap().loss as f64
    }

    #[test]
    fn presets_have_consistent_shapes() {
        for name in ["tiny", "mnist", "cifar", "celeba"] {
            let be = NativeBackend::for_preset(name).unwrap();
            let p = be.preset();
            assert_eq!(p.num_channels * p.chan_size, p.dbar, "{name}");
            let (wd, ws) = be.init_params().unwrap();
            assert_eq!(wd.n_params(), p.nd_params, "{name}");
            assert_eq!(ws.n_params(), p.ns_params, "{name}");
            // deterministic init
            let (wd2, _) = be.init_params().unwrap();
            assert_eq!(wd.data, wd2.data, "{name}");
        }
        assert!(NativeBackend::for_preset("nope").is_err());
    }

    #[test]
    fn device_fwd_shape_nonneg_deterministic() {
        let be = NativeBackend::for_preset("tiny").unwrap();
        let (wd, _) = be.init_params().unwrap();
        let (x, _) = batch_xy(&be, 1);
        let f1 = be.device_fwd(&wd, &x).unwrap();
        assert_eq!((f1.rows, f1.cols), (8, 32));
        assert!(f1.data.iter().all(|&v| v >= 0.0 && v.is_finite()));
        let f2 = be.device_fwd(&wd, &x).unwrap();
        assert_eq!(f1, f2);
    }

    #[test]
    fn zero_cotangent_gives_zero_device_grads() {
        let be = NativeBackend::for_preset("tiny").unwrap();
        let (wd, _) = be.init_params().unwrap();
        let (x, _) = batch_xy(&be, 2);
        let zeros = Matrix::zeros(8, 32);
        let g = be.device_bwd(&wd, &x, &zeros).unwrap();
        assert!(g.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn feature_stats_matches_host_oracle() {
        let be = NativeBackend::for_preset("tiny").unwrap();
        let (wd, _) = be.init_params().unwrap();
        let (x, _) = batch_xy(&be, 3);
        let f = be.device_fwd(&wd, &x).unwrap();
        let sigma = be.feature_stats(&f).unwrap();
        let expect = normalized_sigma(&column_stats(&f), 4);
        assert_eq!(sigma, expect);
        // dispersion varies across columns (Fig.-1 premise)
        let mx = sigma.iter().cloned().fold(0.0f32, f32::max);
        let mn = sigma.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(mx > mn);
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let logits = Matrix::zeros(4, 5);
        let mut y = vec![0.0f32; 20];
        for b in 0..4 {
            y[b * 5 + b] = 1.0;
        }
        let (loss, _, dl) = softmax_xent(&logits, &y);
        assert!((loss - (5.0f32).ln()).abs() < 1e-5, "loss={loss}");
        // gradient rows sum to zero and have -0.8/B at the label
        for b in 0..4 {
            let row = dl.row(b);
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-6);
            assert!((row[b] - (0.2 - 1.0) / 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn directional_gradient_check() {
        // Central finite differences along random directions vs the analytic
        // backward pass, for both parameter sets. ReLU kinks contribute only
        // O(eps) error, so a 5% relative tolerance is comfortable.
        let be = small();
        let (wd, ws) = be.init_params().unwrap();
        let (x, y) = batch_xy(&be, 7);

        let f = be.device_fwd(&wd, &x).unwrap();
        let out = be.server_fwd_bwd(&ws, &f, &y).unwrap();
        let grad_wd = be.device_bwd(&wd, &x, &out.g).unwrap();
        let eps = 1e-3f32;

        let mut rng = Rng::new(1234);
        for trial in 0..4 {
            // server-side direction
            let dir_s: Vec<f32> = (0..ws.n_params()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let analytic: f64 = out
                .grad_ws
                .iter()
                .zip(&dir_s)
                .map(|(&g, &d)| g as f64 * d as f64)
                .sum();
            let mut wsp = ws.clone();
            let mut wsm = ws.clone();
            for i in 0..ws.n_params() {
                wsp.data[i] += eps * dir_s[i];
                wsm.data[i] -= eps * dir_s[i];
            }
            let numeric = (loss_at(&be, &wd, &wsp, &x, &y)
                - loss_at(&be, &wd, &wsm, &x, &y))
                / (2.0 * eps as f64);
            assert!(
                (numeric - analytic).abs() <= 0.05 * analytic.abs() + 2e-3,
                "server trial {trial}: numeric {numeric} vs analytic {analytic}"
            );

            // device-side direction
            let dir_d: Vec<f32> = (0..wd.n_params()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let analytic: f64 = grad_wd
                .iter()
                .zip(&dir_d)
                .map(|(&g, &d)| g as f64 * d as f64)
                .sum();
            let mut wdp = wd.clone();
            let mut wdm = wd.clone();
            for i in 0..wd.n_params() {
                wdp.data[i] += eps * dir_d[i];
                wdm.data[i] -= eps * dir_d[i];
            }
            let numeric = (loss_at(&be, &wdp, &ws, &x, &y)
                - loss_at(&be, &wdm, &ws, &x, &y))
                / (2.0 * eps as f64);
            assert!(
                (numeric - analytic).abs() <= 0.05 * analytic.abs() + 2e-3,
                "device trial {trial}: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn few_sgd_steps_reduce_loss() {
        // Plain gradient descent on one fixed batch must overfit it.
        let be = small();
        let (mut wd, mut ws) = be.init_params().unwrap();
        let (x, y) = batch_xy(&be, 11);
        let first = loss_at(&be, &wd, &ws, &x, &y);
        for _ in 0..200 {
            let f = be.device_fwd(&wd, &x).unwrap();
            let out = be.server_fwd_bwd(&ws, &f, &y).unwrap();
            let gd = be.device_bwd(&wd, &x, &out.g).unwrap();
            for (w, g) in ws.data.iter_mut().zip(&out.grad_ws) {
                *w -= 0.2 * g;
            }
            for (w, g) in wd.data.iter_mut().zip(&gd) {
                *w -= 0.2 * g;
            }
        }
        let last = loss_at(&be, &wd, &ws, &x, &y);
        assert!(last < first * 0.6, "loss {first} -> {last}");
    }

    #[test]
    fn eval_logits_composes_device_and_server() {
        let be = NativeBackend::for_preset("tiny").unwrap();
        let (wd, ws) = be.init_params().unwrap();
        let (x, y) = batch_xy(&be, 5);
        let logits = be.eval_logits(&wd, &ws, &x).unwrap();
        assert_eq!(logits.len(), 8 * 4);
        // consistency: loss from server_fwd_bwd on F equals softmax-xent of
        // the composed logits for the same labels
        let f = be.device_fwd(&wd, &x).unwrap();
        let out = be.server_fwd_bwd(&ws, &f, &y).unwrap();
        let lm = Matrix::from_vec(8, 4, logits);
        let (loss, _, _) = softmax_xent(&lm, &y);
        assert!((out.loss - loss).abs() < 1e-5);
    }
}
