//! Row-major f32 matrix — the host-side representation of the paper's
//! intermediate feature/gradient matrices (`B x Dbar`, eq. 3 / eq. 5).

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy out column `c` (row-major storage makes columns strided).
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn set_col(&mut self, c: usize, vals: &[f32]) {
        assert_eq!(vals.len(), self.rows);
        for (r, &v) in vals.iter().enumerate() {
            *self.at_mut(r, c) = v;
        }
    }

    /// Multiply column `c` in place by `s`.
    pub fn scale_col(&mut self, c: usize, s: f32) {
        for r in 0..self.rows {
            *self.at_mut(r, c) *= s;
        }
    }

    /// New matrix keeping only the columns in `idx` (order preserved).
    pub fn gather_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = &mut out.data[r * idx.len()..(r + 1) * idx.len()];
            for (j, &c) in idx.iter().enumerate() {
                dst[j] = src[c];
            }
        }
        out
    }

    /// Inverse of `gather_cols`: place our columns at positions `idx` of a
    /// `rows x full_cols` zero matrix.
    pub fn scatter_cols(&self, idx: &[usize], full_cols: usize) -> Matrix {
        assert_eq!(idx.len(), self.cols);
        let mut out = Matrix::zeros(self.rows, full_cols);
        for r in 0..self.rows {
            let src = self.row(r);
            for (j, &c) in idx.iter().enumerate() {
                out.data[r * full_cols + c] = src[j];
            }
        }
        out
    }

    /// Squared Frobenius distance to `other`.
    pub fn sq_dist(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&a| (a as f64) * (a as f64)).sum()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Matrix {
        Matrix::from_fn(3, 4, |r, c| (r * 10 + c) as f32)
    }

    #[test]
    fn index_layout_row_major() {
        let a = m();
        assert_eq!(a.at(0, 0), 0.0);
        assert_eq!(a.at(1, 2), 12.0);
        assert_eq!(a.row(2), &[20.0, 21.0, 22.0, 23.0]);
        assert_eq!(a.col(1), vec![1.0, 11.0, 21.0]);
    }

    #[test]
    fn gather_then_scatter_roundtrips_kept_columns() {
        let a = m();
        let idx = vec![0, 2, 3];
        let g = a.gather_cols(&idx);
        assert_eq!(g.cols, 3);
        assert_eq!(g.col(1), a.col(2));
        let s = g.scatter_cols(&idx, 4);
        assert_eq!(s.col(0), a.col(0));
        assert_eq!(s.col(2), a.col(2));
        assert_eq!(s.col(1), vec![0.0; 3]); // dropped column zeroed
    }

    #[test]
    fn scale_col() {
        let mut a = m();
        a.scale_col(3, 2.0);
        assert_eq!(a.col(3), vec![6.0, 26.0, 46.0]);
    }

    #[test]
    fn sq_dist_and_norm() {
        let a = m();
        let mut b = a.clone();
        *b.at_mut(0, 0) += 3.0;
        assert_eq!(a.sq_dist(&b), 9.0);
        assert_eq!(Matrix::zeros(2, 2).sq_norm(), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        Matrix::from_vec(2, 2, vec![0.0; 5]);
    }
}
