//! Row-major f32 matrix — the host-side representation of the paper's
//! intermediate feature/gradient matrices (`B x Dbar`, eq. 3 / eq. 5).

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy out column `c` (row-major storage makes columns strided).
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn set_col(&mut self, c: usize, vals: &[f32]) {
        assert_eq!(vals.len(), self.rows);
        for (r, &v) in vals.iter().enumerate() {
            *self.at_mut(r, c) = v;
        }
    }

    /// Multiply column `c` in place by `s`.
    pub fn scale_col(&mut self, c: usize, s: f32) {
        for r in 0..self.rows {
            *self.at_mut(r, c) *= s;
        }
    }

    /// New matrix keeping only the columns in `idx` (order preserved).
    pub fn gather_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = &mut out.data[r * idx.len()..(r + 1) * idx.len()];
            for (j, &c) in idx.iter().enumerate() {
                dst[j] = src[c];
            }
        }
        out
    }

    /// Inverse of `gather_cols`: place our columns at positions `idx` of a
    /// `rows x full_cols` zero matrix.
    pub fn scatter_cols(&self, idx: &[usize], full_cols: usize) -> Matrix {
        assert_eq!(idx.len(), self.cols);
        let mut out = Matrix::zeros(self.rows, full_cols);
        for r in 0..self.rows {
            let src = self.row(r);
            for (j, &c) in idx.iter().enumerate() {
                out.data[r * full_cols + c] = src[j];
            }
        }
        out
    }

    /// Dense product `self · other` (self: n×m, other: m×p → n×p).
    ///
    /// ikj loop order: the inner loop streams one row of `other` against one
    /// output row, so every access is contiguous and autovectorizes — this is
    /// the hot kernel of the native execution backend.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul: {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let p = other.cols;
        let mut out = Matrix::zeros(self.rows, p);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * p..(i + 1) * p];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &other.data[k * p..(k + 1) * p];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    /// Transposed-left product `selfᵀ · other` (self: n×m, other: n×p → m×p)
    /// without materializing the transpose — the gradient-accumulation shape
    /// (`Xᵀ·dZ`) of the native backward pass.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn: {}x{} vs {}x{}", self.rows, self.cols, other.rows, other.cols);
        let p = other.cols;
        let mut out = Matrix::zeros(self.cols, p);
        for r in 0..self.rows {
            let arow = self.row(r);
            let brow = other.row(r);
            for (i, &ai) in arow.iter().enumerate() {
                if ai == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * p..(i + 1) * p];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += ai * b;
                }
            }
        }
        out
    }

    /// Transposed-right product `self · otherᵀ` (self: n×m, other: p×m → n×p)
    /// — the activation-gradient shape (`dZ·Wᵀ`) of the backward pass; both
    /// operands are read row-contiguously.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt: {}x{} vs {}x{}", self.rows, self.cols, other.rows, other.cols);
        let p = other.rows;
        let mut out = Matrix::zeros(self.rows, p);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * p..(i + 1) * p];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = other.row(j);
                *o = arow.iter().zip(brow).map(|(&a, &b)| a * b).sum();
            }
        }
        out
    }

    /// Add `v` to every row (broadcast bias add). `v.len() == cols`.
    pub fn add_row_vec(&mut self, v: &[f32]) {
        assert_eq!(v.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (x, &b) in row.iter_mut().zip(v) {
                *x += b;
            }
        }
    }

    /// In-place ReLU.
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Zero every entry where the same-position entry of `pre` is ≤ 0 — the
    /// ReLU backward mask (`pre` is the pre-activation matrix).
    pub fn relu_mask(&mut self, pre: &Matrix) {
        assert_eq!((self.rows, self.cols), (pre.rows, pre.cols));
        for (v, &z) in self.data.iter_mut().zip(&pre.data) {
            if z <= 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Column sums (the bias-gradient reduction).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(r)) {
                *o += v;
            }
        }
        out
    }

    /// Squared Frobenius distance to `other`.
    pub fn sq_dist(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&a| (a as f64) * (a as f64)).sum()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Matrix {
        Matrix::from_fn(3, 4, |r, c| (r * 10 + c) as f32)
    }

    #[test]
    fn index_layout_row_major() {
        let a = m();
        assert_eq!(a.at(0, 0), 0.0);
        assert_eq!(a.at(1, 2), 12.0);
        assert_eq!(a.row(2), &[20.0, 21.0, 22.0, 23.0]);
        assert_eq!(a.col(1), vec![1.0, 11.0, 21.0]);
    }

    #[test]
    fn gather_then_scatter_roundtrips_kept_columns() {
        let a = m();
        let idx = vec![0, 2, 3];
        let g = a.gather_cols(&idx);
        assert_eq!(g.cols, 3);
        assert_eq!(g.col(1), a.col(2));
        let s = g.scatter_cols(&idx, 4);
        assert_eq!(s.col(0), a.col(0));
        assert_eq!(s.col(2), a.col(2));
        assert_eq!(s.col(1), vec![0.0; 3]); // dropped column zeroed
    }

    #[test]
    fn scale_col() {
        let mut a = m();
        a.scale_col(3, 2.0);
        assert_eq!(a.col(3), vec![6.0, 26.0, 46.0]);
    }

    #[test]
    fn sq_dist_and_norm() {
        let a = m();
        let mut b = a.clone();
        *b.at_mut(0, 0) += 3.0;
        assert_eq!(a.sq_dist(&b), 9.0);
        assert_eq!(Matrix::zeros(2, 2).sq_norm(), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        Matrix::from_vec(2, 2, vec![0.0; 5]);
    }

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular_matches_naive() {
        let a = Matrix::from_fn(3, 5, |r, c| (r * 5 + c) as f32 * 0.5 - 3.0);
        let b = Matrix::from_fn(5, 4, |r, c| ((r * 4 + c) % 7) as f32 - 2.0);
        let got = a.matmul(&b);
        assert_eq!((got.rows, got.cols), (3, 4));
        for i in 0..3 {
            for j in 0..4 {
                let naive: f32 = (0..5).map(|k| a.at(i, k) * b.at(k, j)).sum();
                assert!((got.at(i, j) - naive).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn matmul_tn_equals_transpose_then_matmul() {
        let a = Matrix::from_fn(4, 3, |r, c| (r + 2 * c) as f32 - 2.0);
        let b = Matrix::from_fn(4, 5, |r, c| (r * c) as f32 * 0.1 + 1.0);
        let at = Matrix::from_fn(3, 4, |r, c| a.at(c, r));
        let want = at.matmul(&b);
        let got = a.matmul_tn(&b);
        assert_eq!((got.rows, got.cols), (3, 5));
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_nt_equals_matmul_with_transpose() {
        let a = Matrix::from_fn(2, 4, |r, c| (r * 4 + c) as f32);
        let b = Matrix::from_fn(3, 4, |r, c| (c as f32 - r as f32) * 0.5);
        let bt = Matrix::from_fn(4, 3, |r, c| b.at(c, r));
        let want = a.matmul(&bt);
        let got = a.matmul_nt(&b);
        assert_eq!((got.rows, got.cols), (2, 3));
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn bias_relu_mask_and_colsums() {
        let mut a = Matrix::from_vec(2, 3, vec![1.0, -2.0, 0.5, -1.0, 3.0, -0.5]);
        a.add_row_vec(&[0.0, 1.0, -0.5]);
        assert_eq!(a.data, vec![1.0, -1.0, 0.0, -1.0, 4.0, -1.0]);
        let pre = a.clone();
        a.relu_inplace();
        assert_eq!(a.data, vec![1.0, 0.0, 0.0, 0.0, 4.0, 0.0]);
        let mut g = Matrix::from_vec(2, 3, vec![1.0; 6]);
        g.relu_mask(&pre);
        // pre > 0 only at (0,0) and (1,1)
        assert_eq!(g.data, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        assert_eq!(g.col_sums(), vec![1.0, 1.0, 0.0]);
    }
}
