//! Row-major f32 matrix — the host-side representation of the paper's
//! intermediate feature/gradient matrices (`B x Dbar`, eq. 3 / eq. 5).
//!
//! The three matmul shapes (`A·B`, `Aᵀ·B`, `A·Bᵀ`) are the hot kernels of
//! the native execution backend. They are register-blocked (4-row/4-column
//! micro-kernels), cache-tiled over the shared dimension, and parallelized
//! over output row blocks through `util::par`. Serial (`threads = 1`) and
//! threaded runs execute the identical kernel on identical blocks, so
//! results are bit-identical across thread counts. The pre-blocking scalar
//! loops survive as `*_ref` — the correctness oracle for the property tests
//! and the serial baseline the perf benches measure against.

use crate::util::{par, simd};

/// Rows of the left operand per register micro-kernel.
const MR: usize = 4;
/// Tile over the shared (reduction) dimension — keeps the streamed rows of
/// the right operand resident in cache across one row block.
const KC: usize = 256;
/// Multiply-adds below which a matmul runs as a single block on the calling
/// thread. The pool spawns fresh scoped threads per call (~tens of µs), so
/// only kernels in the ≳0.5 ms range are worth fanning out; the mnist-scale
/// shapes (≈5-30 M madds) clear this easily, the tiny preset never does.
const PAR_WORK_MIN: usize = 1 << 20;

/// Output-rows-per-chunk for a `rows`-row result with `work` total madds:
/// one chunk (serial) for small problems, else ~4 chunks per worker capped
/// at 32 rows so the claimed block stays cache-sized.
fn block_rows(rows: usize, work: usize) -> usize {
    if work < PAR_WORK_MIN {
        return rows.max(1);
    }
    let target = 4 * par::threads();
    let rb = (rows + target - 1) / target;
    // round up to a multiple of MR so the register micro-kernel runs on
    // full blocks even when many workers shrink the chunk (tail rows then
    // exist only in the final chunk)
    let rb = ((rb + MR - 1) / MR) * MR;
    rb.clamp(1, 32.min(rows.max(1)))
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy out column `c` (row-major storage makes columns strided).
    pub fn col(&self, c: usize) -> Vec<f32> {
        self.col_iter(c).collect()
    }

    /// Strided iterator over column `c` — the allocation-free way to walk a
    /// column on hot paths (the FWQ entry-code loop, column-energy sums).
    #[inline]
    pub fn col_iter(&self, c: usize) -> impl Iterator<Item = f32> + '_ {
        debug_assert!(c < self.cols);
        // skip (not slicing) so an empty matrix yields an empty iterator
        self.data.iter().skip(c).step_by(self.cols.max(1)).copied()
    }

    pub fn set_col(&mut self, c: usize, vals: &[f32]) {
        assert_eq!(vals.len(), self.rows);
        for (r, &v) in vals.iter().enumerate() {
            *self.at_mut(r, c) = v;
        }
    }

    /// Multiply column `c` in place by `s`.
    pub fn scale_col(&mut self, c: usize, s: f32) {
        debug_assert!(c < self.cols);
        let stride = self.cols.max(1);
        for v in self.data.iter_mut().skip(c).step_by(stride) {
            *v *= s;
        }
    }

    /// Multiply each column `idx[j]` in place by `scale[j]` — one row-major
    /// pass instead of `idx.len()` strided `scale_col` sweeps (the downlink
    /// chain-rule rescale of eq. 7).
    pub fn scale_cols(&mut self, idx: &[usize], scale: &[f32]) {
        assert_eq!(idx.len(), scale.len());
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (&c, &s) in idx.iter().zip(scale) {
                row[c] *= s;
            }
        }
    }

    /// New matrix keeping only the columns in `idx` (order preserved).
    pub fn gather_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = &mut out.data[r * idx.len()..(r + 1) * idx.len()];
            for (j, &c) in idx.iter().enumerate() {
                dst[j] = src[c];
            }
        }
        out
    }

    /// `gather_cols` fused with a per-kept-column scale — the FWDP encode
    /// path (gather kept columns, apply 1/(1-p_j)) in a single pass.
    pub fn gather_cols_scaled(&self, idx: &[usize], scale: &[f32]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        self.gather_cols_scaled_into(idx, scale, &mut out);
        out
    }

    /// [`Matrix::gather_cols`] into a caller-owned matrix (resized in place,
    /// capacity reused) — the arena-backed scalar-codec staging path.
    pub fn gather_cols_into(&self, idx: &[usize], out: &mut Matrix) {
        out.rows = self.rows;
        out.cols = idx.len();
        out.data.clear();
        out.data.resize(self.rows * idx.len(), 0.0);
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = &mut out.data[r * idx.len()..(r + 1) * idx.len()];
            for (j, &c) in idx.iter().enumerate() {
                dst[j] = src[c];
            }
        }
    }

    /// [`Matrix::gather_cols_scaled`] into a caller-owned matrix.
    pub fn gather_cols_scaled_into(&self, idx: &[usize], scale: &[f32], out: &mut Matrix) {
        assert_eq!(idx.len(), scale.len());
        out.rows = self.rows;
        out.cols = idx.len();
        out.data.clear();
        out.data.resize(self.rows * idx.len(), 0.0);
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = &mut out.data[r * idx.len()..(r + 1) * idx.len()];
            for (j, (&c, &s)) in idx.iter().zip(scale).enumerate() {
                dst[j] = src[c] * s;
            }
        }
    }

    /// Inverse of `gather_cols`: place our columns at positions `idx` of a
    /// `rows x full_cols` zero matrix.
    pub fn scatter_cols(&self, idx: &[usize], full_cols: usize) -> Matrix {
        let mut out = Matrix::zeros(self.rows, full_cols);
        self.scatter_cols_into(idx, &mut out);
        out
    }

    /// `scatter_cols` into a caller-owned (pre-zeroed) matrix — the wire hot
    /// path reuses a scratch-arena matrix instead of allocating per step.
    /// Positions outside `idx` are left untouched.
    pub fn scatter_cols_into(&self, idx: &[usize], out: &mut Matrix) {
        assert_eq!(idx.len(), self.cols);
        assert_eq!(out.rows, self.rows, "scatter_cols_into: row mismatch");
        let full_cols = out.cols;
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = &mut out.data[r * full_cols..(r + 1) * full_cols];
            for (j, &c) in idx.iter().enumerate() {
                dst[c] = src[j];
            }
        }
    }

    /// Dense product `self · other` (self: n×m, other: m×p → n×p).
    ///
    /// Register-blocked (4 output rows share each streamed row of `other`),
    /// tiled over the shared dimension, parallelized over output row blocks.
    /// Each output element still accumulates its k-terms in ascending order,
    /// so the result is bit-identical for any thread count.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul: {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (n, m, p) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(n, p);
        if n == 0 || m == 0 || p == 0 {
            return out;
        }
        let a = &self.data;
        let b = &other.data;
        let rb = block_rows(n, n * m * p);
        par::par_chunks_mut(&mut out.data, rb * p, |blk, chunk| {
            mm_block(a, m, b, p, chunk, blk * rb);
        });
        out
    }

    /// Transposed-left product `selfᵀ · other` (self: n×m, other: n×p → m×p)
    /// without materializing the transpose — the gradient-accumulation shape
    /// (`Xᵀ·dZ`) of the native backward pass. Blocked and threaded like
    /// [`Matrix::matmul`]; output rows (columns of `self`) are the parallel
    /// axis, and 4 rows of `other` are fused per pass over a row block.
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn: {}x{} vs {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (n, m, p) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, p);
        if n == 0 || m == 0 || p == 0 {
            return out;
        }
        let a = &self.data;
        let b = &other.data;
        let rb = block_rows(m, n * m * p);
        par::par_chunks_mut(&mut out.data, rb * p, |blk, chunk| {
            tn_block(a, m, b, p, chunk, blk * rb, n);
        });
        out
    }

    /// Transposed-right product `self · otherᵀ` (self: n×m, other: p×m → n×p)
    /// — the activation-gradient shape (`dZ·Wᵀ`) of the backward pass; both
    /// operands are read row-contiguously. Four dot products run per pass so
    /// the row of `self` is loaded once per four outputs, and row blocks of
    /// the result are computed in parallel.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt: {}x{} vs {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (n, m, p) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(n, p);
        if n == 0 || m == 0 || p == 0 {
            return out;
        }
        let a = &self.data;
        let b = &other.data;
        let rb = block_rows(n, n * m * p);
        if simd::mode() == simd::SimdMode::Avx2 {
            // the nt inner loop runs along the reduction dimension, which the
            // bit-exactness contract forbids vectorizing. Transpose `other`
            // once and run the A·B kernel instead: out[i][j] accumulates its
            // k-terms ascending from 0.0 either way (the nt `s += x*b[k]`
            // chain and the mm `o += x*bk[j]` chain are the same sequence,
            // KC tiling included), so this path is bit-identical to nt_block.
            let mut bt = vec![0.0f32; m * p];
            for (rr, brow) in b.chunks_exact(m).enumerate() {
                for (kk, &v) in brow.iter().enumerate() {
                    bt[kk * p + rr] = v;
                }
            }
            par::par_chunks_mut(&mut out.data, rb * p, |blk, chunk| {
                mm_block(a, m, &bt, p, chunk, blk * rb);
            });
            return out;
        }
        par::par_chunks_mut(&mut out.data, rb * p, |blk, chunk| {
            nt_block(a, m, b, p, chunk, blk * rb);
        });
        out
    }

    /// Pre-blocking scalar `self · other` — correctness oracle for the
    /// blocked kernel and the serial baseline of the perf benches.
    pub fn matmul_ref(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul_ref: {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let p = other.cols;
        let mut out = Matrix::zeros(self.rows, p);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * p..(i + 1) * p];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &other.data[k * p..(k + 1) * p];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    /// Pre-blocking scalar `selfᵀ · other` (oracle / bench baseline).
    pub fn matmul_tn_ref(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn_ref: {}x{} vs {}x{}", self.rows, self.cols, other.rows, other.cols);
        let p = other.cols;
        let mut out = Matrix::zeros(self.cols, p);
        for r in 0..self.rows {
            let arow = self.row(r);
            let brow = other.row(r);
            for (i, &ai) in arow.iter().enumerate() {
                if ai == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * p..(i + 1) * p];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += ai * b;
                }
            }
        }
        out
    }

    /// Pre-blocking scalar `self · otherᵀ` (oracle / bench baseline).
    pub fn matmul_nt_ref(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt_ref: {}x{} vs {}x{}", self.rows, self.cols, other.rows, other.cols);
        let p = other.rows;
        let mut out = Matrix::zeros(self.rows, p);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * p..(i + 1) * p];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = other.row(j);
                *o = arow.iter().zip(brow).map(|(&a, &b)| a * b).sum();
            }
        }
        out
    }

    /// Add `v` to every row (broadcast bias add). `v.len() == cols`.
    pub fn add_row_vec(&mut self, v: &[f32]) {
        assert_eq!(v.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (x, &b) in row.iter_mut().zip(v) {
                *x += b;
            }
        }
    }

    /// In-place ReLU.
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Zero every entry where the same-position entry of `pre` is ≤ 0 — the
    /// ReLU backward mask (`pre` is the pre-activation matrix).
    pub fn relu_mask(&mut self, pre: &Matrix) {
        assert_eq!((self.rows, self.cols), (pre.rows, pre.cols));
        for (v, &z) in self.data.iter_mut().zip(&pre.data) {
            if z <= 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Column sums (the bias-gradient reduction).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(r)) {
                *o += v;
            }
        }
        out
    }

    /// Squared Frobenius distance to `other`.
    pub fn sq_dist(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&a| (a as f64) * (a as f64)).sum()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// `A·B` over one output row block. `out` holds rows `i0..i0 + out.len()/p`
/// of the result; `a` is n×m row-major, `b` is m×p row-major.
///
/// Loop nest: k-tile outer (rows `k0..k1` of `b` stay cache-hot), then a
/// 4-row micro-kernel whose inner j-loop reads each `b` row once for four
/// output rows. All five slices have length `p`, so the indexing bounds-check
/// folds away and the loop vectorizes.
fn mm_block(a: &[f32], m: usize, b: &[f32], p: usize, out: &mut [f32], i0: usize) {
    let kr = simd::kernels();
    let rows = out.len() / p;
    for k0 in (0..m).step_by(KC) {
        let k1 = (k0 + KC).min(m);
        let mut i = 0;
        while i + MR <= rows {
            let a0 = &a[(i0 + i) * m..][k0..k1];
            let a1 = &a[(i0 + i + 1) * m..][k0..k1];
            let a2 = &a[(i0 + i + 2) * m..][k0..k1];
            let a3 = &a[(i0 + i + 3) * m..][k0..k1];
            let block = &mut out[i * p..(i + MR) * p];
            let (o0, rest) = block.split_at_mut(p);
            let (o1, rest) = rest.split_at_mut(p);
            let (o2, o3) = rest.split_at_mut(p);
            for (k, (((&x0, &x1), &x2), &x3)) in
                a0.iter().zip(a1).zip(a2).zip(a3).enumerate()
            {
                let bk = &b[(k0 + k) * p..(k0 + k + 1) * p];
                (kr.mm4)(o0, o1, o2, o3, [x0, x1, x2, x3], bk);
            }
            i += MR;
        }
        // tail rows (< MR)
        for ii in i..rows {
            let ai = &a[(i0 + ii) * m..][k0..k1];
            let orow = &mut out[ii * p..(ii + 1) * p];
            for (k, &x) in ai.iter().enumerate() {
                let bk = &b[(k0 + k) * p..(k0 + k + 1) * p];
                (kr.axpy)(orow, x, bk);
            }
        }
    }
}

/// `Aᵀ·B` over one output row block: rows `i0..` of the m×p result, i.e.
/// columns `i0..` of the n×m `a`. Four rows of `a`/`b` are consumed per
/// pass, so each output row is rewritten n/4 times instead of n.
fn tn_block(a: &[f32], m: usize, b: &[f32], p: usize, out: &mut [f32], i0: usize, n: usize) {
    let kr = simd::kernels();
    let rows = out.len() / p;
    let mut r = 0;
    while r + MR <= n {
        let b0 = &b[r * p..(r + 1) * p];
        let b1 = &b[(r + 1) * p..(r + 2) * p];
        let b2 = &b[(r + 2) * p..(r + 3) * p];
        let b3 = &b[(r + 3) * p..(r + 4) * p];
        for i in 0..rows {
            let x0 = a[r * m + i0 + i];
            let x1 = a[(r + 1) * m + i0 + i];
            let x2 = a[(r + 2) * m + i0 + i];
            let x3 = a[(r + 3) * m + i0 + i];
            let orow = &mut out[i * p..(i + 1) * p];
            (kr.tn4)(orow, [x0, x1, x2, x3], b0, b1, b2, b3);
        }
        r += MR;
    }
    for rr in r..n {
        let brow = &b[rr * p..(rr + 1) * p];
        for i in 0..rows {
            let x = a[rr * m + i0 + i];
            let orow = &mut out[i * p..(i + 1) * p];
            (kr.axpy)(orow, x, brow);
        }
    }
}

/// `A·Bᵀ` over one output row block: four independent dot products per pass
/// (four accumulator chains hide the FP-add latency; the `a` row is read
/// once per four outputs).
fn nt_block(a: &[f32], m: usize, b: &[f32], p: usize, out: &mut [f32], i0: usize) {
    let rows = out.len() / p;
    for i in 0..rows {
        let arow = &a[(i0 + i) * m..(i0 + i + 1) * m];
        let orow = &mut out[i * p..(i + 1) * p];
        let mut j = 0;
        while j + MR <= p {
            let b0 = &b[j * m..(j + 1) * m];
            let b1 = &b[(j + 1) * m..(j + 2) * m];
            let b2 = &b[(j + 2) * m..(j + 3) * m];
            let b3 = &b[(j + 3) * m..(j + 4) * m];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (k, &x) in arow.iter().enumerate() {
                s0 += x * b0[k];
                s1 += x * b1[k];
                s2 += x * b2[k];
                s3 += x * b3[k];
            }
            orow[j] = s0;
            orow[j + 1] = s1;
            orow[j + 2] = s2;
            orow[j + 3] = s3;
            j += MR;
        }
        while j < p {
            let brow = &b[j * m..(j + 1) * m];
            orow[j] = arow.iter().zip(brow).map(|(&x, &y)| x * y).sum();
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Matrix {
        Matrix::from_fn(3, 4, |r, c| (r * 10 + c) as f32)
    }

    #[test]
    fn index_layout_row_major() {
        let a = m();
        assert_eq!(a.at(0, 0), 0.0);
        assert_eq!(a.at(1, 2), 12.0);
        assert_eq!(a.row(2), &[20.0, 21.0, 22.0, 23.0]);
        assert_eq!(a.col(1), vec![1.0, 11.0, 21.0]);
    }

    #[test]
    fn gather_then_scatter_roundtrips_kept_columns() {
        let a = m();
        let idx = vec![0, 2, 3];
        let g = a.gather_cols(&idx);
        assert_eq!(g.cols, 3);
        assert_eq!(g.col(1), a.col(2));
        let s = g.scatter_cols(&idx, 4);
        assert_eq!(s.col(0), a.col(0));
        assert_eq!(s.col(2), a.col(2));
        assert_eq!(s.col(1), vec![0.0; 3]); // dropped column zeroed
    }

    #[test]
    fn scale_col() {
        let mut a = m();
        a.scale_col(3, 2.0);
        assert_eq!(a.col(3), vec![6.0, 26.0, 46.0]);
    }

    #[test]
    fn col_iter_matches_col() {
        let a = m();
        for c in 0..4 {
            assert_eq!(a.col_iter(c).collect::<Vec<_>>(), a.col(c));
        }
        assert_eq!(Matrix::zeros(0, 3).col_iter(2).count(), 0);
    }

    #[test]
    fn scale_cols_fused_matches_scale_col() {
        let mut a = m();
        let mut b = m();
        a.scale_cols(&[1, 3], &[0.5, 2.0]);
        b.scale_col(1, 0.5);
        b.scale_col(3, 2.0);
        assert_eq!(a, b);
    }

    #[test]
    fn gather_cols_scaled_fuses_gather_and_scale() {
        let a = m();
        let idx = vec![0, 2];
        let got = a.gather_cols_scaled(&idx, &[2.0, 3.0]);
        let mut want = a.gather_cols(&idx);
        want.scale_col(0, 2.0);
        want.scale_col(1, 3.0);
        assert_eq!(got, want);
    }

    #[test]
    fn blocked_kernels_match_reference_on_awkward_shapes() {
        // shapes straddling the MR/KC boundaries, including degenerate ones
        for &(n, mm, p) in &[(1, 1, 1), (3, 5, 2), (4, 4, 4), (5, 300, 3), (7, 13, 9), (9, 257, 5)] {
            let a = Matrix::from_fn(n, mm, |r, c| ((r * 31 + c * 7) % 11) as f32 * 0.3 - 1.0);
            let b = Matrix::from_fn(mm, p, |r, c| ((r * 5 + c * 3) % 13) as f32 * 0.2 - 1.2);
            let got = a.matmul(&b);
            let want = a.matmul_ref(&b);
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!((x - y).abs() < 1e-4, "{n}x{mm}x{p}");
            }
            let c2 = Matrix::from_fn(n, p, |r, c| (r as f32 - c as f32) * 0.1);
            let got = a.matmul_tn(&c2);
            let want = a.matmul_tn_ref(&c2);
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!((x - y).abs() < 1e-4, "tn {n}x{mm}x{p}");
            }
            let d = Matrix::from_fn(p, mm, |r, c| ((r + c) % 7) as f32 * 0.25 - 0.5);
            let got = a.matmul_nt(&d);
            let want = a.matmul_nt_ref(&d);
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!((x - y).abs() < 1e-4, "nt {n}x{mm}x{p}");
            }
        }
    }

    #[test]
    fn sq_dist_and_norm() {
        let a = m();
        let mut b = a.clone();
        *b.at_mut(0, 0) += 3.0;
        assert_eq!(a.sq_dist(&b), 9.0);
        assert_eq!(Matrix::zeros(2, 2).sq_norm(), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        Matrix::from_vec(2, 2, vec![0.0; 5]);
    }

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular_matches_naive() {
        let a = Matrix::from_fn(3, 5, |r, c| (r * 5 + c) as f32 * 0.5 - 3.0);
        let b = Matrix::from_fn(5, 4, |r, c| ((r * 4 + c) % 7) as f32 - 2.0);
        let got = a.matmul(&b);
        assert_eq!((got.rows, got.cols), (3, 4));
        for i in 0..3 {
            for j in 0..4 {
                let naive: f32 = (0..5).map(|k| a.at(i, k) * b.at(k, j)).sum();
                assert!((got.at(i, j) - naive).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn matmul_tn_equals_transpose_then_matmul() {
        let a = Matrix::from_fn(4, 3, |r, c| (r + 2 * c) as f32 - 2.0);
        let b = Matrix::from_fn(4, 5, |r, c| (r * c) as f32 * 0.1 + 1.0);
        let at = Matrix::from_fn(3, 4, |r, c| a.at(c, r));
        let want = at.matmul(&b);
        let got = a.matmul_tn(&b);
        assert_eq!((got.rows, got.cols), (3, 5));
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_nt_equals_matmul_with_transpose() {
        let a = Matrix::from_fn(2, 4, |r, c| (r * 4 + c) as f32);
        let b = Matrix::from_fn(3, 4, |r, c| (c as f32 - r as f32) * 0.5);
        let bt = Matrix::from_fn(4, 3, |r, c| b.at(c, r));
        let want = a.matmul(&bt);
        let got = a.matmul_nt(&b);
        assert_eq!((got.rows, got.cols), (2, 3));
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn bias_relu_mask_and_colsums() {
        let mut a = Matrix::from_vec(2, 3, vec![1.0, -2.0, 0.5, -1.0, 3.0, -0.5]);
        a.add_row_vec(&[0.0, 1.0, -0.5]);
        assert_eq!(a.data, vec![1.0, -1.0, 0.0, -1.0, 4.0, -1.0]);
        let pre = a.clone();
        a.relu_inplace();
        assert_eq!(a.data, vec![1.0, 0.0, 0.0, 0.0, 4.0, 0.0]);
        let mut g = Matrix::from_vec(2, 3, vec![1.0; 6]);
        g.relu_mask(&pre);
        // pre > 0 only at (0,0) and (1,1)
        assert_eq!(g.data, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        assert_eq!(g.col_sums(), vec![1.0, 1.0, 0.0]);
    }
}
