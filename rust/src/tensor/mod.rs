//! Host-side tensor substrate: row-major f32 matrices + column statistics.

pub mod matrix;
pub mod stats;

pub use matrix::Matrix;
pub use stats::{
    channel_min_max, column_stats, dispersion_summary, normalized_sigma, ColumnStats,
    DispersionSummary,
};
