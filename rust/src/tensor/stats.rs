//! Column / channel statistics over intermediate matrices.
//!
//! On the hot path FWDP gets these from the AOT `feature_stats` artifact
//! (the L1 Pallas kernel); this module is the host-side reference (used by
//! codecs on *compressed* matrices whose width D̂ is dynamic, by tests as an
//! oracle against the kernel, and by the Fig.-1 dispersion bench).

use super::matrix::Matrix;

#[derive(Clone, Debug, Default)]
pub struct ColumnStats {
    pub min: Vec<f32>,
    pub max: Vec<f32>,
    pub mean: Vec<f32>,
    /// stddev of the raw column values (population, 1/B).
    pub std: Vec<f32>,
}

impl ColumnStats {
    pub fn range(&self, i: usize) -> f32 {
        self.max[i] - self.min[i]
    }

    pub fn ranges(&self) -> Vec<f32> {
        (0..self.min.len()).map(|i| self.range(i)).collect()
    }
}

/// Columns per parallel work item — large enough that each worker streams a
/// meaningful slice of every row, small enough that D̄ = 8192 splits across
/// the pool.
const STATS_COL_CHUNK: usize = 512;
/// Total elements below which the scan runs inline — a fresh thread spawn
/// costs more than streaming this much memory.
const STATS_PAR_MIN: usize = 1 << 17;

/// Single pass per column: min / max / mean / std.
///
/// Parallelized over column chunks (each worker scans all rows over its
/// column range). Per-column accumulation stays in row order, so results are
/// bit-identical to a single-threaded pass; small matrices run inline.
pub fn column_stats(m: &Matrix) -> ColumnStats {
    let (b, d) = (m.rows, m.cols);
    assert!(b > 0 && d > 0);
    if b * d < STATS_PAR_MIN {
        return stats_for_cols(m, 0, d);
    }
    let nchunks = (d + STATS_COL_CHUNK - 1) / STATS_COL_CHUNK;
    let parts = crate::util::par::par_map_idx(nchunks, 1, |ci| {
        let c0 = ci * STATS_COL_CHUNK;
        stats_for_cols(m, c0, (c0 + STATS_COL_CHUNK).min(d))
    });
    // splice the chunk results back in column order
    let mut out = ColumnStats {
        min: Vec::with_capacity(d),
        max: Vec::with_capacity(d),
        mean: Vec::with_capacity(d),
        std: Vec::with_capacity(d),
    };
    for p in parts {
        out.min.extend(p.min);
        out.max.extend(p.max);
        out.mean.extend(p.mean);
        out.std.extend(p.std);
    }
    out
}

fn stats_for_cols(m: &Matrix, c0: usize, c1: usize) -> ColumnStats {
    let (b, d) = (m.rows, c1 - c0);
    let mut mn = vec![f32::INFINITY; d];
    let mut mx = vec![f32::NEG_INFINITY; d];
    let mut sum = vec![0.0f64; d];
    let mut sumsq = vec![0.0f64; d];
    // lanes = feature columns: per column the fold order is row order in
    // both kernel tables, so SIMD on/off is bit-identical
    let kr = crate::util::simd::kernels();
    for r in 0..b {
        let row = &m.row(r)[c0..c1];
        (kr.stats_row)(row, &mut mn, &mut mx, &mut sum, &mut sumsq);
    }
    let mut mean = vec![0.0f32; d];
    let mut std = vec![0.0f32; d];
    for c in 0..d {
        let mu = sum[c] / b as f64;
        mean[c] = mu as f32;
        std[c] = (sumsq[c] / b as f64 - mu * mu).max(0.0).sqrt() as f32;
    }
    ColumnStats { min: mn, max: mx, mean, std }
}

/// Per-channel min/max where channel h owns the contiguous column block
/// `[h*chan_size, (h+1)*chan_size)` — the paper's index sets `I_h` (eq. 9).
pub fn channel_min_max(stats: &ColumnStats, chan_size: usize) -> (Vec<f32>, Vec<f32>) {
    let d = stats.min.len();
    assert!(chan_size > 0 && d % chan_size == 0, "D={d} chan={chan_size}");
    let h = d / chan_size;
    let mut mn = vec![f32::INFINITY; h];
    let mut mx = vec![f32::NEG_INFINITY; h];
    for c in 0..d {
        let ch = c / chan_size;
        mn[ch] = mn[ch].min(stats.min[c]);
        mx[ch] = mx[ch].max(stats.max[c]);
    }
    (mn, mx)
}

/// σ_i of the channel-normalized features (paper eq. 10), via the affine
/// identity σ_norm = σ_raw / (channel range); 0 for degenerate channels.
pub fn normalized_sigma(stats: &ColumnStats, chan_size: usize) -> Vec<f32> {
    let (mn, mx) = channel_min_max(stats, chan_size);
    (0..stats.std.len())
        .map(|c| {
            let ch = c / chan_size;
            let r = mx[ch] - mn[ch];
            if r > 0.0 {
                stats.std[c] / r
            } else {
                0.0
            }
        })
        .collect()
}

/// Fig.-1 style dispersion summary of a matrix (std + range extremes and the
/// max / smallest-non-zero ("SNV") ratios the paper highlights).
#[derive(Debug, Clone)]
pub struct DispersionSummary {
    pub std_min: f32,
    pub std_max: f32,
    pub std_snv_ratio: f32,
    pub range_min: f32,
    pub range_max: f32,
    pub range_snv_ratio: f32,
}

pub fn dispersion_summary(std: &[f32], ranges: &[f32]) -> DispersionSummary {
    fn snv_ratio(xs: &[f32]) -> f32 {
        let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let snv = xs
            .iter()
            .cloned()
            .filter(|&x| x > 0.0)
            .fold(f32::INFINITY, f32::min);
        if snv.is_finite() && snv > 0.0 {
            max / snv
        } else {
            0.0
        }
    }
    DispersionSummary {
        std_min: std.iter().cloned().fold(f32::INFINITY, f32::min),
        std_max: std.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
        std_snv_ratio: snv_ratio(std),
        range_min: ranges.iter().cloned().fold(f32::INFINITY, f32::min),
        range_max: ranges.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
        range_snv_ratio: snv_ratio(ranges),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        // 4 rows x 6 cols, 2 channels of 3 columns
        Matrix::from_vec(
            4,
            6,
            vec![
                0.0, 1.0, 2.0, 10.0, 20.0, 30.0, //
                4.0, 1.0, 2.0, 10.0, 22.0, 30.0, //
                2.0, 1.0, 2.0, 14.0, 24.0, 30.0, //
                2.0, 1.0, 2.0, 10.0, 26.0, 30.0,
            ],
        )
    }

    #[test]
    fn stats_basics() {
        let s = column_stats(&sample());
        assert_eq!(s.min[0], 0.0);
        assert_eq!(s.max[0], 4.0);
        assert!((s.mean[0] - 2.0).abs() < 1e-6);
        assert_eq!(s.std[1], 0.0); // constant column
        assert_eq!(s.range(3), 4.0);
    }

    #[test]
    fn stats_match_naive() {
        let m = Matrix::from_fn(7, 5, |r, c| ((r * 31 + c * 17) % 13) as f32 - 6.0);
        let s = column_stats(&m);
        for c in 0..5 {
            let col = m.col(c);
            let mu = col.iter().sum::<f32>() / 7.0;
            let var = col.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 7.0;
            assert!((s.mean[c] - mu).abs() < 1e-5);
            assert!((s.std[c] - var.sqrt()).abs() < 1e-4);
        }
    }

    #[test]
    fn wide_matrix_stats_identical_across_thread_counts() {
        // past both parallel gates (≥ STATS_PAR_MIN elements, > 1 column
        // chunk) so the splice path genuinely runs
        let m = Matrix::from_fn(128, 2 * super::STATS_COL_CHUNK + 37, |r, c| {
            ((r * 131 + c * 17) % 23) as f32 * 0.4 - 4.0
        });
        assert!(m.len() >= super::STATS_PAR_MIN);
        crate::util::par::set_threads(1);
        let s1 = column_stats(&m);
        crate::util::par::set_threads(4);
        let s4 = column_stats(&m);
        crate::util::par::set_threads(0);
        assert_eq!(s1.min, s4.min);
        assert_eq!(s1.max, s4.max);
        assert_eq!(s1.mean, s4.mean);
        assert_eq!(s1.std, s4.std);
        assert_eq!(s1.min.len(), m.cols);
    }

    #[test]
    fn channel_min_max_blocks() {
        let s = column_stats(&sample());
        let (mn, mx) = channel_min_max(&s, 3);
        assert_eq!(mn, vec![0.0, 10.0]);
        assert_eq!(mx, vec![4.0, 30.0]);
    }

    #[test]
    fn normalized_sigma_scale_invariant() {
        let m = sample();
        let mut m2 = m.clone();
        for v in &mut m2.data {
            *v = *v * 100.0 + 5.0;
        }
        // scale whole matrix: channel ranges scale too -> identical sigma_norm
        let s1 = normalized_sigma(&column_stats(&m), 3);
        let s2 = normalized_sigma(&column_stats(&m2), 3);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn normalized_sigma_degenerate_channel_zero() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 1.0, 3.0, 2.0]); // chan 0 constant
        let s = normalized_sigma(&column_stats(&m), 1);
        assert_eq!(s[0], 0.0);
        assert!(s[1] > 0.0);
    }

    #[test]
    fn normalized_sigma_bounded_half() {
        // normalized values live in [0,1] => sigma <= 0.5
        let m = Matrix::from_fn(50, 8, |r, c| ((r * 7 + c * 13) % 17) as f32);
        let s = normalized_sigma(&column_stats(&m), 4);
        assert!(s.iter().all(|&x| x <= 0.5 + 1e-6));
    }

    #[test]
    fn dispersion_summary_ratios() {
        let d = dispersion_summary(&[0.0, 0.1, 0.4], &[0.0, 2.0, 8.0]);
        assert_eq!(d.std_snv_ratio, 4.0);
        assert_eq!(d.range_snv_ratio, 4.0);
        assert_eq!(d.std_min, 0.0);
        assert_eq!(d.range_max, 8.0);
    }
}
