//! Versioned binary checkpoints of the full training state.
//!
//! A checkpoint captures everything a run needs to continue byte-identically
//! after a process restart: both parameter sets and their ADAM slots, the
//! shared Algorithm-1 RNG stream, the per-device run totals, every device's
//! private state (its RNG streams, minibatch-loader shuffle position, codec
//! session including the `splitfc[...,ef]` error-feedback residual — which
//! is *training state*, not a cache — and its schedule position), and the
//! PS-side codec sessions.
//!
//! **Format.** Extends the PR 6 `Msg`/`Frame` idiom: little-endian fields
//! behind a self-describing envelope. The file layout is
//!
//! ```text
//! magic "SPLITFCK" (8)  | format version (u16)
//! header block:   u32 len | CkptHeader bytes | u32 crc32
//! section table:  u32 count | per section: name (u32 len + bytes),
//!                 u64 payload len, u32 crc32
//! payloads, concatenated in table order
//! ```
//!
//! The header carries the codec id/version, fleet shape, round, seed and a
//! trajectory fingerprint, so `splitfc ckpt inspect` can describe a file —
//! and `--resume` can reject a mismatched one — without touching a tensor.
//! Every section is CRC-guarded; [`Checkpoint::decode`] verifies the magic,
//! version and **all** CRCs before returning, so a corrupt or truncated
//! file is rejected before any run state could be mutated from it.
//!
//! **Atomicity / retention.** [`Checkpoint::save`] writes to a `.tmp`
//! sibling and renames into place, then prunes all but the newest
//! `keep` snapshots — a crash mid-write never clobbers the previous good
//! checkpoint.

use std::path::{Path, PathBuf};

use crate::compression::error::CodecError;
use crate::coordinator::protocol::DeviceTotals;
use crate::coordinator::server::{DeviceOptState, ServerSnap};
use crate::data::loader::LoaderState;
use crate::optim::adam::AdamState;
use crate::transport::wire::ByteCursor;
use crate::util::error::Error;
use crate::util::rng::RngState;

/// File magic: the first 8 bytes of every checkpoint.
pub const MAGIC: &[u8; 8] = b"SPLITFCK";

/// Current snapshot format version. Bump on any layout change; old readers
/// reject newer files with a typed [`CkptError::WrongVersion`].
pub const FORMAT_VERSION: u16 = 1;

/// Typed checkpoint errors — `ckpt inspect` and `--resume` report these
/// instead of panicking or half-loading state.
#[derive(Debug, Clone, PartialEq)]
pub enum CkptError {
    Io(String),
    /// The file does not start with the `SPLITFCK` magic.
    BadMagic,
    /// The file's format version is newer than this binary supports.
    WrongVersion { found: u16, supported: u16 },
    /// The file ends before a declared field/section does.
    Truncated { needed: u64, available: u64 },
    /// A CRC mismatch or malformed field inside one section.
    Corrupt { section: String, reason: String },
    /// The checkpoint was taken under a different run configuration.
    ConfigMismatch { field: String, ckpt: String, run: String },
    /// The metrics file on disk does not line up with the snapshot.
    MetricsMismatch { reason: String },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint io: {e}"),
            CkptError::BadMagic => write!(f, "not a splitfc checkpoint (bad magic)"),
            CkptError::WrongVersion { found, supported } => write!(
                f,
                "checkpoint format v{found} is not supported (this binary reads <= v{supported})"
            ),
            CkptError::Truncated { needed, available } => write!(
                f,
                "checkpoint truncated: needed {needed} bytes, {available} available"
            ),
            CkptError::Corrupt { section, reason } => {
                write!(f, "checkpoint section {section:?} corrupt: {reason}")
            }
            CkptError::ConfigMismatch { field, ckpt, run } => write!(
                f,
                "checkpoint/config mismatch on {field}: checkpoint has {ckpt}, run has {run}"
            ),
            CkptError::MetricsMismatch { reason } => {
                write!(f, "metrics file does not match checkpoint: {reason}")
            }
        }
    }
}

impl std::error::Error for CkptError {}

impl From<CkptError> for Error {
    fn from(e: CkptError) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> CkptError {
        CkptError::Io(e.to_string())
    }
}

type CkptResult<T> = std::result::Result<T, CkptError>;

/// Map a bounds-checked cursor error into a section-tagged [`CkptError`].
fn in_section<T>(section: &str, r: Result<T, CodecError>) -> CkptResult<T> {
    r.map_err(|e| match e {
        CodecError::TruncatedFrame { needed, available } => {
            CkptError::Truncated { needed, available }
        }
        other => CkptError::Corrupt { section: section.to_string(), reason: other.to_string() },
    })
}

/// CRC-32 (IEEE 802.3, reflected poly 0xEDB88320) — the same checksum gzip
/// uses; hand-rolled bitwise since the offline registry has no crc crate.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// ---- primitive field encoding (little-endian, PR 6 message idiom) ----

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

fn put_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    put_u64(out, xs.len() as u64);
    out.reserve(xs.len() * 8);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_rng(out: &mut Vec<u8>, st: &RngState) {
    for w in st.s {
        put_u64(out, w);
    }
    match st.gauss {
        Some(z) => {
            put_u8(out, 1);
            put_f64(out, z);
        }
        None => put_u8(out, 0),
    }
}

fn get_str(sec: &str, cur: &mut ByteCursor<'_>) -> CkptResult<String> {
    let n = in_section(sec, cur.u32())? as usize;
    let bytes = in_section(sec, cur.take(n))?;
    String::from_utf8(bytes.to_vec()).map_err(|_| CkptError::Corrupt {
        section: sec.to_string(),
        reason: "non-utf8 string field".to_string(),
    })
}

fn get_bytes(sec: &str, cur: &mut ByteCursor<'_>) -> CkptResult<Vec<u8>> {
    let n = in_section(sec, cur.u32())? as usize;
    Ok(in_section(sec, cur.take(n))?.to_vec())
}

fn get_f32s(sec: &str, cur: &mut ByteCursor<'_>) -> CkptResult<Vec<f32>> {
    let n = in_section(sec, cur.u64())? as usize;
    // bounds-check the count before allocating (adversarial length prefix)
    let raw = in_section(sec, cur.take(n.checked_mul(4).unwrap_or(usize::MAX)))?;
    Ok(raw.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

fn get_u64s(sec: &str, cur: &mut ByteCursor<'_>) -> CkptResult<Vec<u64>> {
    let n = in_section(sec, cur.u64())? as usize;
    let raw = in_section(sec, cur.take(n.checked_mul(8).unwrap_or(usize::MAX)))?;
    Ok(raw
        .chunks_exact(8)
        .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
        .collect())
}

fn get_rng(sec: &str, cur: &mut ByteCursor<'_>) -> CkptResult<RngState> {
    let mut s = [0u64; 4];
    for w in &mut s {
        *w = in_section(sec, cur.u64())?;
    }
    let gauss = match in_section(sec, cur.u8())? {
        0 => None,
        1 => Some(in_section(sec, cur.f64())?),
        other => {
            return Err(CkptError::Corrupt {
                section: sec.to_string(),
                reason: format!("bad rng gauss flag {other}"),
            })
        }
    };
    Ok(RngState { s, gauss })
}

// ---- header ----

/// Self-describing run identity, readable without decoding any tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptHeader {
    pub format: u16,
    /// Versioned codec id of the run's scheme (`compression::codec_id`).
    pub codec_id: u32,
    pub codec_version: u16,
    /// Canonical codec spec name, e.g. `splitfc[ad,R=8,fwq,ef]`.
    pub scheme: String,
    pub preset: String,
    pub devices: u32,
    pub rounds: u32,
    /// The round this snapshot was taken after (watermark = round·devices).
    pub round: u32,
    pub seed: u64,
    /// FNV-1a over every trajectory-determining config field
    /// (`TrainConfig::trajectory_fingerprint`).
    pub fingerprint: u64,
    pub scenario: String,
}

impl CkptHeader {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, self.codec_id);
        put_u16(&mut out, self.codec_version);
        put_str(&mut out, &self.scheme);
        put_str(&mut out, &self.preset);
        put_u32(&mut out, self.devices);
        put_u32(&mut out, self.rounds);
        put_u32(&mut out, self.round);
        put_u64(&mut out, self.seed);
        put_u64(&mut out, self.fingerprint);
        put_str(&mut out, &self.scenario);
        out
    }

    fn decode(format: u16, bytes: &[u8]) -> CkptResult<CkptHeader> {
        const SEC: &str = "header";
        let mut cur = ByteCursor::new(bytes);
        let h = CkptHeader {
            format,
            codec_id: in_section(SEC, cur.u32())?,
            codec_version: in_section(SEC, cur.u16())?,
            scheme: get_str(SEC, &mut cur)?,
            preset: get_str(SEC, &mut cur)?,
            devices: in_section(SEC, cur.u32())?,
            rounds: in_section(SEC, cur.u32())?,
            round: in_section(SEC, cur.u32())?,
            seed: in_section(SEC, cur.u64())?,
            fingerprint: in_section(SEC, cur.u64())?,
            scenario: get_str(SEC, &mut cur)?,
        };
        if !cur.is_empty() {
            return Err(CkptError::Corrupt {
                section: SEC.to_string(),
                reason: format!("{} trailing bytes", cur.remaining()),
            });
        }
        Ok(h)
    }
}

// ---- device-side snapshot (travels over the protocol as a blob) ----

/// Everything a `DeviceWorker` owns that determines its trajectory: its
/// RNG streams, the loader's shuffle order/position, its codec session
/// (EF residual) and its schedule position. Encoded to an opaque blob that
/// rides `Commit` up and `HelloAck` back down, so remote devices checkpoint
/// and restore through the PS without a side channel.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSnap {
    pub rng: RngState,
    pub backoff_rng: RngState,
    pub loader: LoaderState,
    /// Opaque `Codec::export_session` bytes (device-side session).
    pub codec: Vec<u8>,
    /// Steps this worker has begun (drives scenario `cut[...,step=N]`).
    pub steps_run: u64,
}

impl DeviceSnap {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_rng(&mut out, &self.rng);
        put_rng(&mut out, &self.backoff_rng);
        put_u64s(&mut out, &self.loader.indices);
        put_u64(&mut out, self.loader.cursor);
        put_u64(&mut out, self.loader.batch);
        put_rng(&mut out, &self.loader.rng);
        put_bytes(&mut out, &self.codec);
        put_u64(&mut out, self.steps_run);
        out
    }

    pub fn decode(bytes: &[u8]) -> CkptResult<DeviceSnap> {
        const SEC: &str = "device";
        let mut cur = ByteCursor::new(bytes);
        let rng = get_rng(SEC, &mut cur)?;
        let backoff_rng = get_rng(SEC, &mut cur)?;
        let indices = get_u64s(SEC, &mut cur)?;
        let cursor = in_section(SEC, cur.u64())?;
        let batch = in_section(SEC, cur.u64())?;
        let loader_rng = get_rng(SEC, &mut cur)?;
        let codec = get_bytes(SEC, &mut cur)?;
        let steps_run = in_section(SEC, cur.u64())?;
        if !cur.is_empty() {
            return Err(CkptError::Corrupt {
                section: SEC.to_string(),
                reason: format!("{} trailing bytes", cur.remaining()),
            });
        }
        Ok(DeviceSnap {
            rng,
            backoff_rng,
            loader: LoaderState { indices, cursor, batch, rng: loader_rng },
            codec,
            steps_run,
        })
    }
}

// ---- sections ----

/// Scheduler/metrics position: where the run resumes and what the metrics
/// stream looked like at the snapshot barrier.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedSnap {
    /// Global step count at the barrier (`first_step + round·devices`):
    /// every metrics record written so far carries `g` strictly below it.
    pub boundary_g: u64,
    /// Byte length of the metrics JSONL at the barrier — `--resume`
    /// truncates the file back to this before appending.
    pub metrics_len: u64,
    pub totals: Vec<DeviceTotals>,
}

/// Per-device-link state held at the PS: the PS-side codec session and the
/// latest device-side [`DeviceSnap`] blob (None if the device never
/// committed a step, e.g. a scenario departure before its first turn).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LinkSnap {
    pub ps_session: Vec<u8>,
    pub device: Option<Vec<u8>>,
}

fn encode_server(s: &ServerSnap) -> Vec<u8> {
    let mut out = Vec::new();
    put_f32s(&mut out, &s.wd);
    put_f32s(&mut out, &s.ws);
    put_adam(&mut out, &s.opt_s);
    match &s.opt_d {
        DeviceOptState::Shared(a) => {
            put_u8(&mut out, 0);
            put_adam(&mut out, a);
        }
        DeviceOptState::PerDevice(opts) => {
            put_u8(&mut out, 1);
            put_u32(&mut out, opts.len() as u32);
            for a in opts {
                put_adam(&mut out, a);
            }
        }
    }
    put_rng(&mut out, &s.rng);
    put_f64(&mut out, s.exec_s);
    out
}

fn put_adam(out: &mut Vec<u8>, a: &AdamState) {
    put_u64(out, a.t);
    put_f32s(out, &a.m);
    put_f32s(out, &a.v);
}

fn get_adam(sec: &str, cur: &mut ByteCursor<'_>) -> CkptResult<AdamState> {
    Ok(AdamState {
        t: in_section(sec, cur.u64())?,
        m: get_f32s(sec, cur)?,
        v: get_f32s(sec, cur)?,
    })
}

fn decode_server(bytes: &[u8]) -> CkptResult<ServerSnap> {
    const SEC: &str = "server";
    let mut cur = ByteCursor::new(bytes);
    let wd = get_f32s(SEC, &mut cur)?;
    let ws = get_f32s(SEC, &mut cur)?;
    let opt_s = get_adam(SEC, &mut cur)?;
    let opt_d = match in_section(SEC, cur.u8())? {
        0 => DeviceOptState::Shared(get_adam(SEC, &mut cur)?),
        1 => {
            let n = in_section(SEC, cur.u32())? as usize;
            let mut opts = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                opts.push(get_adam(SEC, &mut cur)?);
            }
            DeviceOptState::PerDevice(opts)
        }
        other => {
            return Err(CkptError::Corrupt {
                section: SEC.to_string(),
                reason: format!("bad DeviceOpt tag {other}"),
            })
        }
    };
    let rng = get_rng(SEC, &mut cur)?;
    let exec_s = in_section(SEC, cur.f64())?;
    if !cur.is_empty() {
        return Err(CkptError::Corrupt {
            section: SEC.to_string(),
            reason: format!("{} trailing bytes", cur.remaining()),
        });
    }
    Ok(ServerSnap { wd, ws, opt_s, opt_d, rng, exec_s })
}

fn encode_sched(s: &SchedSnap) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, s.boundary_g);
    put_u64(&mut out, s.metrics_len);
    put_u32(&mut out, s.totals.len() as u32);
    for t in &s.totals {
        put_u64(&mut out, t.up_bits);
        put_u64(&mut out, t.down_bits);
        put_u64(&mut out, t.steps as u64);
        put_f32(&mut out, t.last_round_loss);
        put_u8(&mut out, t.departed as u8);
    }
    out
}

fn decode_sched(bytes: &[u8]) -> CkptResult<SchedSnap> {
    const SEC: &str = "sched";
    let mut cur = ByteCursor::new(bytes);
    let boundary_g = in_section(SEC, cur.u64())?;
    let metrics_len = in_section(SEC, cur.u64())?;
    let n = in_section(SEC, cur.u32())? as usize;
    let mut totals = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        totals.push(DeviceTotals {
            up_bits: in_section(SEC, cur.u64())?,
            down_bits: in_section(SEC, cur.u64())?,
            steps: in_section(SEC, cur.u64())? as usize,
            last_round_loss: in_section(SEC, cur.f32())?,
            departed: in_section(SEC, cur.u8())? != 0,
        });
    }
    if !cur.is_empty() {
        return Err(CkptError::Corrupt {
            section: SEC.to_string(),
            reason: format!("{} trailing bytes", cur.remaining()),
        });
    }
    Ok(SchedSnap { boundary_g, metrics_len, totals })
}

fn encode_links(links: &[LinkSnap]) -> Vec<u8> {
    let mut out = Vec::new();
    put_u32(&mut out, links.len() as u32);
    for l in links {
        put_bytes(&mut out, &l.ps_session);
        match &l.device {
            Some(b) => {
                put_u8(&mut out, 1);
                put_bytes(&mut out, b);
            }
            None => put_u8(&mut out, 0),
        }
    }
    out
}

fn decode_links(bytes: &[u8]) -> CkptResult<Vec<LinkSnap>> {
    const SEC: &str = "links";
    let mut cur = ByteCursor::new(bytes);
    let n = in_section(SEC, cur.u32())? as usize;
    let mut links = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let ps_session = get_bytes(SEC, &mut cur)?;
        let device = match in_section(SEC, cur.u8())? {
            0 => None,
            1 => Some(get_bytes(SEC, &mut cur)?),
            other => {
                return Err(CkptError::Corrupt {
                    section: SEC.to_string(),
                    reason: format!("bad device-blob flag {other}"),
                })
            }
        };
        links.push(LinkSnap { ps_session, device });
    }
    if !cur.is_empty() {
        return Err(CkptError::Corrupt {
            section: SEC.to_string(),
            reason: format!("{} trailing bytes", cur.remaining()),
        });
    }
    Ok(links)
}

// ---- the checkpoint itself ----

/// One complete run snapshot, taken at a round barrier where the watermark
/// has quiesced (no step in flight).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub header: CkptHeader,
    pub server: ServerSnap,
    pub sched: SchedSnap,
    pub links: Vec<LinkSnap>,
}

impl Checkpoint {
    /// Canonical file name for a snapshot taken after `round`.
    pub fn file_name(round: u32) -> String {
        format!("ckpt-r{round:05}.splitfc")
    }

    pub fn encode(&self) -> Vec<u8> {
        let header = self.header.encode();
        let sections: [(&str, Vec<u8>); 3] = [
            ("server", encode_server(&self.server)),
            ("sched", encode_sched(&self.sched)),
            ("links", encode_links(&self.links)),
        ];
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        put_u16(&mut out, self.header.format);
        put_u32(&mut out, header.len() as u32);
        out.extend_from_slice(&header);
        put_u32(&mut out, crc32(&header));
        put_u32(&mut out, sections.len() as u32);
        for (name, payload) in &sections {
            put_str(&mut out, name);
            put_u64(&mut out, payload.len() as u64);
            put_u32(&mut out, crc32(payload));
        }
        for (_, payload) in &sections {
            out.extend_from_slice(payload);
        }
        out
    }

    /// Decode and fully verify a snapshot: magic, format version, header
    /// CRC, and every section CRC are checked **before** any section is
    /// decoded, so a caller that only mutates state after a successful
    /// return can never half-apply a corrupt file.
    pub fn decode(bytes: &[u8]) -> CkptResult<Checkpoint> {
        let (header, table, payload_base) = parse_envelope(bytes)?;
        let mut sections = std::collections::HashMap::new();
        let mut off = payload_base;
        for entry in &table {
            let end = off + entry.len as usize;
            let payload = &bytes[off..end];
            sections.insert(entry.name.clone(), payload);
            off = end;
        }
        let need = |name: &str| {
            sections.get(name).copied().ok_or_else(|| CkptError::Corrupt {
                section: name.to_string(),
                reason: "section missing".to_string(),
            })
        };
        Ok(Checkpoint {
            server: decode_server(need("server")?)?,
            sched: decode_sched(need("sched")?)?,
            links: decode_links(need("links")?)?,
            header,
        })
    }

    pub fn load(path: impl AsRef<Path>) -> CkptResult<Checkpoint> {
        let bytes = std::fs::read(path.as_ref())?;
        Checkpoint::decode(&bytes)
    }

    /// Atomically write this snapshot into `dir` (write `.tmp`, fsync,
    /// rename) and prune all but the newest `keep` checkpoints. Returns
    /// the final path.
    pub fn save(&self, dir: impl AsRef<Path>, keep: usize) -> CkptResult<PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let name = Self::file_name(self.header.round);
        let tmp = dir.join(format!("{name}.tmp"));
        let path = dir.join(&name);
        let bytes = self.encode();
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &path)?;
        prune(dir, keep.max(1))?;
        Ok(path)
    }
}

/// Sorted list of checkpoint files in `dir` (oldest round first).
pub fn list(dir: impl AsRef<Path>) -> CkptResult<Vec<PathBuf>> {
    let mut found = Vec::new();
    let entries = match std::fs::read_dir(dir.as_ref()) {
        Ok(e) => e,
        Err(_) => return Ok(found), // no directory yet: nothing retained
    };
    for entry in entries {
        let p = entry?.path();
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("ckpt-r") && name.ends_with(".splitfc") {
            found.push(p);
        }
    }
    found.sort();
    Ok(found)
}

fn prune(dir: &Path, keep: usize) -> CkptResult<()> {
    let found = list(dir)?;
    if found.len() > keep {
        for p in &found[..found.len() - keep] {
            std::fs::remove_file(p)?;
        }
    }
    Ok(())
}

/// Remove stale `ckpt-r*.splitfc.tmp` files from `dir`. A crash between
/// [`Checkpoint::save`]'s write and its rename leaks the `.tmp` sibling
/// forever; the trainer sweeps at startup so they cannot accumulate.
/// Returns how many were removed; a missing directory sweeps nothing.
pub fn sweep_tmp(dir: impl AsRef<Path>) -> CkptResult<usize> {
    let entries = match std::fs::read_dir(dir.as_ref()) {
        Ok(e) => e,
        Err(_) => return Ok(0),
    };
    let mut swept = 0;
    for entry in entries {
        let p = entry?.path();
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("ckpt-r") && name.ends_with(".splitfc.tmp") {
            std::fs::remove_file(&p)?;
            swept += 1;
        }
    }
    Ok(swept)
}

// ---- inspection (header + table only, tensors never decoded) ----

#[derive(Debug, Clone)]
pub struct SectionInfo {
    pub name: String,
    pub len: u64,
    pub crc: u32,
}

/// What `splitfc ckpt inspect` prints: the header plus the section table,
/// with every CRC verified against the raw payload ranges.
#[derive(Debug, Clone)]
pub struct CkptInfo {
    pub header: CkptHeader,
    pub sections: Vec<SectionInfo>,
    pub file_len: u64,
}

/// Parse the envelope (magic, version, header, section table) and verify
/// the header CRC and every section CRC over the raw byte ranges. Returns
/// the header, the table, and the offset of the first payload byte.
fn parse_envelope(bytes: &[u8]) -> CkptResult<(CkptHeader, Vec<SectionInfo>, usize)> {
    let mut cur = ByteCursor::new(bytes);
    let magic = in_section("envelope", cur.take(8))?;
    if magic != MAGIC {
        return Err(CkptError::BadMagic);
    }
    let format = in_section("envelope", cur.u16())?;
    if format > FORMAT_VERSION {
        return Err(CkptError::WrongVersion { found: format, supported: FORMAT_VERSION });
    }
    let hlen = in_section("envelope", cur.u32())? as usize;
    let hbytes = in_section("envelope", cur.take(hlen))?.to_vec();
    let hcrc = in_section("envelope", cur.u32())?;
    if crc32(&hbytes) != hcrc {
        return Err(CkptError::Corrupt {
            section: "header".to_string(),
            reason: format!("crc mismatch (stored {hcrc:#010x}, computed {:#010x})", crc32(&hbytes)),
        });
    }
    let header = CkptHeader::decode(format, &hbytes)?;
    let count = in_section("envelope", cur.u32())? as usize;
    if count > 64 {
        return Err(CkptError::Corrupt {
            section: "envelope".to_string(),
            reason: format!("implausible section count {count}"),
        });
    }
    let mut table = Vec::with_capacity(count);
    for _ in 0..count {
        let name = get_str("envelope", &mut cur)?;
        let len = in_section("envelope", cur.u64())?;
        let crc = in_section("envelope", cur.u32())?;
        table.push(SectionInfo { name, len, crc });
    }
    let payload_base = bytes.len() - cur.remaining();
    // verify every payload range before anyone decodes anything
    let mut off = payload_base;
    for entry in &table {
        let len = usize::try_from(entry.len).map_err(|_| CkptError::Truncated {
            needed: entry.len,
            available: (bytes.len() - off) as u64,
        })?;
        let end = off.checked_add(len).filter(|&e| e <= bytes.len()).ok_or(
            CkptError::Truncated {
                needed: entry.len,
                available: (bytes.len() - off) as u64,
            },
        )?;
        let got = crc32(&bytes[off..end]);
        if got != entry.crc {
            return Err(CkptError::Corrupt {
                section: entry.name.clone(),
                reason: format!("crc mismatch (stored {:#010x}, computed {got:#010x})", entry.crc),
            });
        }
        off = end;
    }
    if off != bytes.len() {
        return Err(CkptError::Corrupt {
            section: "envelope".to_string(),
            reason: format!("{} trailing bytes after last section", bytes.len() - off),
        });
    }
    Ok((header, table, payload_base))
}

/// Inspect a checkpoint file: header + section table + CRC verification,
/// without decoding any tensor payload.
pub fn inspect(path: impl AsRef<Path>) -> CkptResult<CkptInfo> {
    let bytes = std::fs::read(path.as_ref())?;
    let (header, sections, _) = parse_envelope(&bytes)?;
    Ok(CkptInfo { header, sections, file_len: bytes.len() as u64 })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            header: CkptHeader {
                format: FORMAT_VERSION,
                codec_id: 0xDEAD_BEEF,
                codec_version: 3,
                scheme: "splitfc[ad,R=8,fwq,ef]".to_string(),
                preset: "tiny".to_string(),
                devices: 2,
                rounds: 9,
                round: 4,
                seed: 42,
                fingerprint: 0x1234_5678_9ABC_DEF0,
                scenario: "seed=7,straggler[dev=1,slow=4x]".to_string(),
            },
            server: ServerSnap {
                wd: vec![1.0, -2.5, 0.0],
                ws: vec![0.25; 5],
                opt_s: AdamState { t: 7, m: vec![0.1; 5], v: vec![0.2; 5] },
                opt_d: DeviceOptState::PerDevice(vec![
                    AdamState { t: 3, m: vec![0.0; 3], v: vec![0.5; 3] },
                    AdamState { t: 4, m: vec![1.0; 3], v: vec![2.0; 3] },
                ]),
                rng: RngState { s: [1, 2, 3, 4], gauss: Some(0.75) },
                exec_s: 1.5,
            },
            sched: SchedSnap {
                boundary_g: 8,
                metrics_len: 1234,
                totals: vec![
                    DeviceTotals {
                        up_bits: 100,
                        down_bits: 200,
                        steps: 4,
                        last_round_loss: f32::NAN,
                        departed: false,
                    },
                    DeviceTotals {
                        up_bits: 300,
                        down_bits: 400,
                        steps: 4,
                        last_round_loss: 0.5,
                        departed: true,
                    },
                ],
            },
            links: vec![
                LinkSnap { ps_session: vec![9, 8, 7], device: Some(vec![1, 2, 3, 4]) },
                LinkSnap { ps_session: Vec::new(), device: None },
            ],
        }
    }

    fn assert_ckpt_eq(a: &Checkpoint, b: &Checkpoint) {
        assert_eq!(a.header, b.header);
        assert_eq!(a.server.wd, b.server.wd);
        assert_eq!(a.server.ws, b.server.ws);
        assert_eq!(a.server.opt_s, b.server.opt_s);
        assert_eq!(a.server.opt_d, b.server.opt_d);
        assert_eq!(a.server.rng, b.server.rng);
        assert_eq!(a.server.exec_s, b.server.exec_s);
        assert_eq!(a.sched.boundary_g, b.sched.boundary_g);
        assert_eq!(a.sched.metrics_len, b.sched.metrics_len);
        assert_eq!(a.sched.totals.len(), b.sched.totals.len());
        for (x, y) in a.sched.totals.iter().zip(&b.sched.totals) {
            assert_eq!(x.up_bits, y.up_bits);
            assert_eq!(x.down_bits, y.down_bits);
            assert_eq!(x.steps, y.steps);
            assert_eq!(x.last_round_loss.to_bits(), y.last_round_loss.to_bits());
            assert_eq!(x.departed, y.departed);
        }
        assert_eq!(a.links, b.links);
    }

    #[test]
    fn crc32_known_vector() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_is_exact() {
        let c = sample();
        let bytes = c.encode();
        let d = Checkpoint::decode(&bytes).unwrap();
        assert_ckpt_eq(&c, &d);
    }

    #[test]
    fn device_snap_roundtrips() {
        let snap = DeviceSnap {
            rng: RngState { s: [5, 6, 7, 8], gauss: None },
            backoff_rng: RngState { s: [9, 10, 11, 12], gauss: Some(-1.25) },
            loader: LoaderState {
                indices: vec![3, 1, 4, 1, 5],
                cursor: 2,
                batch: 8,
                rng: RngState { s: [13, 14, 15, 16], gauss: None },
            },
            codec: vec![0xAB; 17],
            steps_run: 42,
        };
        assert_eq!(DeviceSnap::decode(&snap.encode()).unwrap(), snap);
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        assert_eq!(Checkpoint::decode(&bytes).unwrap_err(), CkptError::BadMagic);
    }

    #[test]
    fn wrong_version_is_typed() {
        let mut bytes = sample().encode();
        bytes[8] = 0xFF; // format version LE low byte
        bytes[9] = 0x00;
        assert!(matches!(
            Checkpoint::decode(&bytes).unwrap_err(),
            CkptError::WrongVersion { found: 255, supported: FORMAT_VERSION }
        ));
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        // CRC coverage: flipping any one byte of the file must be rejected
        let good = sample().encode();
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x5A;
            assert!(
                Checkpoint::decode(&bad).is_err(),
                "byte flip at offset {i} went undetected"
            );
        }
        assert!(Checkpoint::decode(&good).is_ok());
    }

    #[test]
    fn truncation_at_every_byte_is_rejected() {
        let good = sample().encode();
        for cut in 0..good.len() {
            let err = Checkpoint::decode(&good[..cut])
                .expect_err("truncated checkpoint must not decode");
            assert!(
                matches!(
                    err,
                    CkptError::Truncated { .. } | CkptError::BadMagic | CkptError::Corrupt { .. }
                ),
                "cut={cut}: {err}"
            );
        }
    }

    #[test]
    fn save_is_atomic_and_retention_prunes() {
        let dir = std::env::temp_dir()
            .join(format!("splitfc_ckpt_test_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut c = sample();
        for round in 1..=5u32 {
            c.header.round = round;
            c.save(&dir, 3).unwrap();
        }
        let kept = list(&dir).unwrap();
        let names: Vec<String> = kept
            .iter()
            .map(|p| p.file_name().unwrap().to_str().unwrap().to_string())
            .collect();
        assert_eq!(
            names,
            vec!["ckpt-r00003.splitfc", "ckpt-r00004.splitfc", "ckpt-r00005.splitfc"]
        );
        // no stray .tmp files survive a completed save
        assert!(std::fs::read_dir(&dir)
            .unwrap()
            .all(|e| !e.unwrap().path().to_str().unwrap().ends_with(".tmp")));
        let loaded = Checkpoint::load(&kept[2]).unwrap();
        assert_eq!(loaded.header.round, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_tmp_removes_only_stale_partial_writes() {
        let dir = std::env::temp_dir()
            .join(format!("splitfc_ckpt_sweep_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        // a missing directory sweeps nothing (and is not an error)
        assert_eq!(sweep_tmp(&dir).unwrap(), 0);

        let c = sample();
        let good = c.save(&dir, 3).unwrap();
        // plant the debris a crash between write and rename leaves behind,
        // plus an unrelated file the sweep must not touch
        let stale = dir.join("ckpt-r00009.splitfc.tmp");
        std::fs::write(&stale, b"half-written").unwrap();
        let other = dir.join("notes.txt");
        std::fs::write(&other, b"keep me").unwrap();

        assert_eq!(sweep_tmp(&dir).unwrap(), 1);
        assert!(!stale.exists(), "stale .tmp must be removed");
        assert!(good.exists(), "real checkpoints must survive the sweep");
        assert!(other.exists(), "unrelated files must survive the sweep");
        assert_eq!(sweep_tmp(&dir).unwrap(), 0, "second sweep finds nothing");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inspect_reads_header_and_verifies_crcs() {
        let dir = std::env::temp_dir()
            .join(format!("splitfc_ckpt_inspect_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let c = sample();
        let path = c.save(&dir, 3).unwrap();
        let info = inspect(&path).unwrap();
        assert_eq!(info.header, c.header);
        let names: Vec<&str> = info.sections.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["server", "sched", "links"]);
        assert_eq!(info.file_len, c.encode().len() as u64);
        // corrupt one payload byte: inspect must flag the owning section
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        match inspect(&path).unwrap_err() {
            CkptError::Corrupt { section, .. } => assert_eq!(section, "links"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
