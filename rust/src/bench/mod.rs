//! Criterion-style micro-benchmark harness (criterion is not in the offline
//! registry). Provides warmup, timed iterations, and robust summary stats
//! (mean / p50 / p95 / MAD), plus a table printer shared by the paper-table
//! benches in `rust/benches/`.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub mad_s: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchStats {
    pub fn report(&self) -> String {
        let tp = self
            .throughput
            .map(|(v, unit)| format!("  {:.3} {unit}", v))
            .unwrap_or_default();
        format!(
            "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  ±{:>9}{tp}",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            fmt_time(self.mad_s),
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

pub struct Bencher {
    /// minimum wall time to spend measuring each benchmark
    pub min_time_s: f64,
    pub warmup_s: f64,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_time_s: 1.0, warmup_s: 0.2, max_iters: 10_000 }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher { min_time_s: 0.3, warmup_s: 0.05, max_iters: 2_000 }
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed().as_secs_f64() < self.warmup_s {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed().as_secs_f64() < self.min_time_s && samples.len() < self.max_iters {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed().as_secs_f64());
        }
        Self::stats(name, samples)
    }

    fn stats(name: &str, mut samples: Vec<f64>) -> BenchStats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let p50 = samples[n / 2];
        let p95 = samples[(n * 95 / 100).min(n - 1)];
        let mut dev: Vec<f64> = samples.iter().map(|&x| (x - p50).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchStats {
            name: name.to_string(),
            iters: n,
            mean_s: mean,
            p50_s: p50,
            p95_s: p95,
            mad_s: dev[n / 2],
            throughput: None,
        }
    }
}

/// Print a paper-style table (rows of label + columns).
pub fn print_table(title: &str, header: &[String], rows: &[(String, Vec<String>)]) {
    println!("\n=== {title} ===");
    let w0 = rows.iter().map(|(l, _)| l.len()).chain([16]).max().unwrap();
    print!("{:<w0$}", "");
    for h in header {
        print!(" | {h:>12}");
    }
    println!();
    println!("{}", "-".repeat(w0 + header.len() * 15));
    for (label, cols) in rows {
        print!("{label:<w0$}");
        for c in cols {
            print!(" | {c:>12}");
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_positive_stats() {
        let b = Bencher { min_time_s: 0.02, warmup_s: 0.0, max_iters: 100 };
        let st = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i * i);
            }
            x
        });
        assert!(st.iters > 0);
        assert!(st.mean_s > 0.0);
        assert!(st.p95_s >= st.p50_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }

    #[test]
    fn stats_sorted_quantiles() {
        let st = Bencher::stats("x", vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(st.p50_s, 3.0);
        assert!(st.p95_s >= st.p50_s);
    }
}
