//! Run configuration: scenario presets mirroring Sec. VII plus CLI overrides.

use crate::compression::{DropKind, FwqMode, ScalarKind, Scheme};
use crate::runtime::BackendKind;
use crate::util::{Args, Json};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    /// MNIST: 2 shards of distinct labels per device [52]
    LabelShards,
    /// CIFAR-100: Dirichlet(0.3) [52]
    Dirichlet,
    /// CelebA: writer grouping [36]
    Writers,
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub preset: String,
    /// Execution backend (native by default; pjrt needs `--features pjrt`).
    pub backend: BackendKind,
    pub artifacts_dir: String,
    /// K — number of devices
    pub devices: usize,
    /// T — communication rounds (each round visits every device once)
    pub rounds: usize,
    pub partition: PartitionKind,
    pub seed: u64,
    pub lr: f32,
    /// uplink budget C_e,d in bits/entry (32 = lossless)
    pub up_bits_per_entry: f64,
    /// downlink budget C_e,s in bits/entry (32 = lossless)
    pub down_bits_per_entry: f64,
    pub scheme: Scheme,
    pub n_train: usize,
    pub n_test: usize,
    /// evaluate every this many rounds (0 = only at the end)
    pub eval_every: usize,
    pub link_capacity_bps: f64,
    pub link_latency_s: f64,
    /// metrics JSONL output ("" = none)
    pub metrics_path: String,
    /// worker threads for the parallel runtime; 0 = unset (the pool is left
    /// as configured, which defaults to one worker per available core)
    pub threads: usize,
    /// bounded-staleness window S in rounds: a device may run up to S rounds
    /// ahead of the slowest outstanding step (≤ S·K protocol steps in
    /// flight). 0 = strict sequential round-robin — byte-identical metrics
    /// to Algorithm 1 even when driven by concurrent workers.
    pub staleness: usize,
    /// device workers driven concurrently. 0 = auto: 1 (inline, no worker
    /// threads) when `staleness == 0`, else one worker per device. Clamped
    /// to `devices`.
    pub concurrent_devices: usize,
    /// give each device its own ADAM moments for the PS-held device-side
    /// model instead of the single shared optimizer of Algorithm 1 (changes
    /// trajectories; off by default)
    pub per_device_opt: bool,
}

impl TrainConfig {
    /// Scenario defaults per preset. Scales (K, T, n) are CPU-feasible
    /// stand-ins for the paper's (30/50/100 devices, 200/100/40 rounds);
    /// paper scales remain reachable via overrides (DESIGN.md §3).
    pub fn for_preset(preset: &str) -> TrainConfig {
        let (devices, rounds, partition, lr, n_train, n_test) = match preset {
            "mnist" => (8, 12, PartitionKind::LabelShards, 1e-3, 4096, 512),
            "cifar" => (8, 10, PartitionKind::Dirichlet, 1e-3, 2048, 256),
            "celeba" => (10, 8, PartitionKind::Writers, 1e-3, 2048, 256),
            // tiny: higher lr — the small native MLP learns in a handful of
            // ADAM steps, which is what the integration tests exercise
            _ => (4, 6, PartitionKind::LabelShards, 1e-2, 512, 64),
        };
        TrainConfig {
            preset: preset.to_string(),
            backend: BackendKind::default(),
            artifacts_dir: "artifacts".to_string(),
            devices,
            rounds,
            partition,
            seed: 0,
            lr,
            up_bits_per_entry: 32.0,
            down_bits_per_entry: 32.0,
            scheme: Scheme::Vanilla,
            n_train,
            n_test,
            eval_every: 0,
            link_capacity_bps: 10e6,
            link_latency_s: 0.0,
            metrics_path: String::new(),
            threads: 0,
            staleness: 0,
            concurrent_devices: 0,
            per_device_opt: false,
        }
    }

    /// Number of scheduler worker threads a run will actually use
    /// (resolves the `concurrent_devices = 0` auto rule and clamps to K).
    pub fn resolved_concurrency(&self) -> usize {
        let want = match self.concurrent_devices {
            0 if self.staleness == 0 => 1,
            0 => self.devices,
            n => n,
        };
        want.clamp(1, self.devices.max(1))
    }

    /// Apply `--key value` CLI overrides.
    pub fn apply_overrides(&mut self, args: &Args) {
        if let Some(v) = args.get("backend") {
            self.backend = BackendKind::parse(v)
                .unwrap_or_else(|e| panic!("{e}"));
        }
        if let Some(v) = args.get("artifacts") {
            self.artifacts_dir = v.to_string();
        }
        self.devices = args.get_usize("devices", self.devices);
        self.rounds = args.get_usize("rounds", self.rounds);
        self.seed = args.get_u64("seed", self.seed);
        self.lr = args.get_f64("lr", self.lr as f64) as f32;
        self.up_bits_per_entry = args.get_f64("up-bpe", self.up_bits_per_entry);
        self.down_bits_per_entry = args.get_f64("down-bpe", self.down_bits_per_entry);
        self.n_train = args.get_usize("n-train", self.n_train);
        self.n_test = args.get_usize("n-test", self.n_test);
        self.eval_every = args.get_usize("eval-every", self.eval_every);
        self.link_capacity_bps = args.get_f64("capacity-bps", self.link_capacity_bps);
        self.threads = args.get_usize("threads", self.threads);
        self.staleness = args.get_usize("staleness", self.staleness);
        self.concurrent_devices =
            args.get_usize("concurrent-devices", self.concurrent_devices);
        if args.has_flag("per-device-opt") {
            self.per_device_opt = true;
        }
        if let Some(v) = args.get("metrics") {
            self.metrics_path = v.to_string();
        }
        if let Some(v) = args.get("partition") {
            self.partition = match v {
                "shards" => PartitionKind::LabelShards,
                "dirichlet" => PartitionKind::Dirichlet,
                "writers" => PartitionKind::Writers,
                other => panic!("unknown partition {other:?}"),
            };
        }
        if let Some(s) = args.get("scheme") {
            self.scheme = parse_scheme(s, args.get_f64("r", 16.0));
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("preset", Json::str(self.preset.clone())),
            ("backend", Json::str(self.backend.name())),
            ("devices", Json::num(self.devices as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("up_bpe", Json::num(self.up_bits_per_entry)),
            ("down_bpe", Json::num(self.down_bits_per_entry)),
            ("scheme", Json::str(self.scheme.name())),
            ("n_train", Json::num(self.n_train as f64)),
            ("n_test", Json::num(self.n_test as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("staleness", Json::num(self.staleness as f64)),
            ("concurrent_devices", Json::num(self.concurrent_devices as f64)),
            ("per_device_opt", Json::Bool(self.per_device_opt)),
        ])
    }
}

/// Parse a framework name (the rows of Tables I-III) into a `Scheme`.
pub fn parse_scheme(name: &str, r: f64) -> Scheme {
    match name {
        "vanilla" => Scheme::Vanilla,
        "splitfc" => Scheme::splitfc(r),
        "splitfc-ad" => Scheme::SplitFc {
            drop: Some(DropKind::Adaptive),
            r,
            quant: FwqMode::NoQuant,
        },
        "splitfc-rand" => Scheme::SplitFc {
            drop: Some(DropKind::Random),
            r,
            quant: FwqMode::NoQuant,
        },
        "splitfc-det" => Scheme::SplitFc {
            drop: Some(DropKind::Deterministic),
            r,
            quant: FwqMode::NoQuant,
        },
        "splitfc-quant-only" => Scheme::SplitFc {
            drop: None,
            r: 1.0,
            quant: FwqMode::Optimal { use_mean: true },
        },
        "splitfc-no-mean" => Scheme::SplitFc {
            drop: Some(DropKind::Adaptive),
            r,
            quant: FwqMode::Optimal { use_mean: false },
        },
        "splitfc-ad+pq" => Scheme::SplitFc {
            drop: Some(DropKind::Adaptive),
            r,
            quant: FwqMode::Scalar(ScalarKind::Pq),
        },
        "splitfc-ad+eq" => Scheme::SplitFc {
            drop: Some(DropKind::Adaptive),
            r,
            quant: FwqMode::Scalar(ScalarKind::Eq),
        },
        "splitfc-ad+nq" => Scheme::SplitFc {
            drop: Some(DropKind::Adaptive),
            r,
            quant: FwqMode::Scalar(ScalarKind::Nq),
        },
        "tops" => Scheme::TopS { theta: 0.0, quant: None },
        "randtops" => Scheme::TopS { theta: 0.2, quant: None },
        "tops+pq" => Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Pq) },
        "tops+eq" => Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Eq) },
        "tops+nq" => Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Nq) },
        "fedlite" => Scheme::FedLite { num_subvectors: 16 },
        other => panic!("unknown scheme {other:?}"),
    }
}

/// The framework lineup of Table I (uplink compression comparison).
pub fn table1_frameworks() -> Vec<&'static str> {
    vec![
        "splitfc",
        "fedlite",
        "randtops",
        "tops",
        "splitfc-ad+pq",
        "splitfc-ad+eq",
        "splitfc-ad+nq",
        "tops+pq",
        "tops+eq",
        "tops+nq",
    ]
}

/// Table II lineup (uplink + downlink compression).
pub fn table2_frameworks() -> Vec<&'static str> {
    vec![
        "splitfc",
        "splitfc-ad+pq",
        "splitfc-ad+eq",
        "splitfc-ad+nq",
        "tops+pq",
        "tops+eq",
        "tops+nq",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_defaults() {
        let c = TrainConfig::for_preset("mnist");
        assert_eq!(c.partition, PartitionKind::LabelShards);
        assert_eq!(TrainConfig::for_preset("cifar").partition, PartitionKind::Dirichlet);
        assert_eq!(TrainConfig::for_preset("celeba").partition, PartitionKind::Writers);
    }

    #[test]
    fn overrides_apply() {
        let mut c = TrainConfig::for_preset("tiny");
        let args = Args::parse(
            &"x --rounds 3 --devices 2 --scheme splitfc --r 8 --up-bpe 0.2 --threads 3"
                .split_whitespace()
                .map(String::from)
                .collect::<Vec<_>>(),
        );
        c.apply_overrides(&args);
        assert_eq!(c.rounds, 3);
        assert_eq!(c.devices, 2);
        assert_eq!(c.up_bits_per_entry, 0.2);
        assert_eq!(c.scheme, Scheme::splitfc(8.0));
        assert_eq!(c.threads, 3);
    }

    #[test]
    fn scheduler_overrides_and_auto_concurrency() {
        let mut c = TrainConfig::for_preset("tiny");
        assert_eq!((c.staleness, c.concurrent_devices), (0, 0));
        assert!(!c.per_device_opt);
        // auto: sequential at S=0, one worker per device otherwise
        assert_eq!(c.resolved_concurrency(), 1);
        c.staleness = 2;
        assert_eq!(c.resolved_concurrency(), c.devices);
        let args = Args::parse(
            &"x --staleness 1 --concurrent-devices 3 --per-device-opt"
                .split_whitespace()
                .map(String::from)
                .collect::<Vec<_>>(),
        );
        c.apply_overrides(&args);
        assert_eq!(c.staleness, 1);
        assert_eq!(c.concurrent_devices, 3);
        assert!(c.per_device_opt);
        assert_eq!(c.resolved_concurrency(), 3);
        // explicit request above K clamps to K
        c.concurrent_devices = 64;
        assert_eq!(c.resolved_concurrency(), c.devices);
    }

    #[test]
    fn all_table_frameworks_parse() {
        for name in table1_frameworks().iter().chain(table2_frameworks().iter()) {
            let _ = parse_scheme(name, 16.0); // must not panic
        }
        for extra in ["vanilla", "splitfc-ad", "splitfc-rand", "splitfc-det",
                      "splitfc-quant-only", "splitfc-no-mean"] {
            let _ = parse_scheme(extra, 8.0);
        }
    }

    #[test]
    #[should_panic]
    fn unknown_scheme_panics() {
        parse_scheme("nope", 1.0);
    }

    #[test]
    fn config_json_roundtrip_fields() {
        let c = TrainConfig::for_preset("mnist");
        let j = c.to_json();
        assert_eq!(j.req("preset").as_str(), Some("mnist"));
        assert_eq!(j.req("devices").as_usize(), Some(8));
        assert_eq!(j.req("backend").as_str(), Some("native"));
    }

    #[test]
    fn backend_override_applies() {
        let mut c = TrainConfig::for_preset("tiny");
        assert_eq!(c.backend, BackendKind::Native);
        let args = Args::parse(
            &"x --backend pjrt".split_whitespace().map(String::from).collect::<Vec<_>>(),
        );
        c.apply_overrides(&args);
        assert_eq!(c.backend, BackendKind::Pjrt);
    }
}
