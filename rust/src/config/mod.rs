//! Run configuration: scenario presets mirroring Sec. VII plus CLI overrides.
//!
//! Compression is configured through [`CodecSpec`] strings resolved by the
//! process-global `CodecRegistry` — `--scheme splitfc[ad,R=8,fwq]`-style
//! specs or any registered legacy alias (`splitfc-ad+pq`, `tops`, ...).
//! Unknown names return an error listing every registered codec instead of
//! panicking.

use crate::compression::{is_registered, registered_names, CodecSpec};
use crate::runtime::BackendKind;
use crate::scenario::ScenarioSpec;
use crate::transport::TransportKind;
use crate::util::error::Result;
use crate::util::{Args, Json};
use crate::{bail, ensure};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    /// MNIST: 2 shards of distinct labels per device [52]
    LabelShards,
    /// CIFAR-100: Dirichlet(0.3) [52]
    Dirichlet,
    /// CelebA: writer grouping [36]
    Writers,
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub preset: String,
    /// Execution backend (native by default; pjrt needs `--features pjrt`).
    pub backend: BackendKind,
    pub artifacts_dir: String,
    /// K — number of devices
    pub devices: usize,
    /// T — communication rounds (each round visits every device once)
    pub rounds: usize,
    pub partition: PartitionKind,
    pub seed: u64,
    pub lr: f32,
    /// uplink budget C_e,d in bits/entry (32 = lossless)
    pub up_bits_per_entry: f64,
    /// downlink budget C_e,s in bits/entry (32 = lossless)
    pub down_bits_per_entry: f64,
    /// compression codec spec, resolved per device through the registry
    pub scheme: CodecSpec,
    /// FWQ endpoint-quantizer levels Q_ep (paper Sec. VII: 200)
    pub q_ep: u64,
    /// shared seed for NoisyQuant's regenerable noise (NQ reproducibility)
    pub noise_seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    /// evaluate every this many rounds (0 = only at the end)
    pub eval_every: usize,
    pub link_capacity_bps: f64,
    pub link_latency_s: f64,
    /// metrics JSONL output ("" = none)
    pub metrics_path: String,
    /// worker threads for the parallel runtime; 0 = unset (the pool is left
    /// as configured, which defaults to one worker per available core)
    pub threads: usize,
    /// SIMD kernel dispatch: "auto" (runtime-detect, the default), "avx2"
    /// (request the vector table; degrades to scalar off-x86), or "off"
    /// (pin the scalar kernels). Both tables are bit-identical — this knob
    /// trades speed only, never trajectories.
    pub simd: String,
    /// bounded-staleness window S in rounds: a device may run up to S rounds
    /// ahead of the slowest outstanding step (≤ S·K protocol steps in
    /// flight). 0 = strict sequential round-robin — byte-identical metrics
    /// to Algorithm 1 even when driven by concurrent workers.
    pub staleness: usize,
    /// device workers driven concurrently. 0 = auto: 1 (inline, no worker
    /// threads) when `staleness == 0`, else one worker per device. Clamped
    /// to `devices`.
    pub concurrent_devices: usize,
    /// give each device its own ADAM moments for the PS-held device-side
    /// model instead of the single shared optimizer of Algorithm 1 (changes
    /// trajectories; off by default)
    pub per_device_opt: bool,
    /// which backend carries device<->PS protocol messages: bounded
    /// in-process channels (default) or length-prefixed TCP frames
    pub transport: TransportKind,
    /// TCP listen address for the PS side (`--transport tcp`); port 0 picks
    /// an ephemeral port, reported by `Trainer::listen_addr`
    pub listen: String,
    /// the last this-many devices are not built in-process: they join over
    /// the listening TCP transport from `splitfc device` processes
    pub devices_remote: usize,
    /// log-normal dispersion of per-device link capacity (0 = uniform
    /// links); draws from a dedicated RNG so trajectories are unaffected
    pub fading_sigma: f64,
    /// seeded failure scenario (`--scenario "seed=7,straggler[dev=2,slow=8x],
    /// dropout[p=0.05,rejoin=2r],cut[dev=1,step=40]"`); empty = calm run,
    /// byte-identical to a run with no scenario machinery at all
    pub scenario: ScenarioSpec,
    /// per-request receive deadline on device connections in seconds
    /// (0 = wait forever); expiry surfaces as a retriable transport fault
    pub rpc_deadline_s: f64,
    /// first backoff delay of the worker's retry loop, milliseconds
    pub retry_base_ms: u64,
    /// backoff delay ceiling, milliseconds
    pub retry_cap_ms: u64,
    /// give up reconnecting after this much cumulative backoff sleep,
    /// seconds (0 = no retries at all)
    pub retry_deadline_s: f64,
    /// PS liveness window in seconds: a device with zero connections that
    /// stays silent this long is marked departed and the run proceeds with
    /// the surviving cohort (0 = wait forever, the historical behavior).
    /// Must exceed the workers' retry deadline or a transient outage may
    /// be declared a departure while the device is still backing off.
    pub liveness_timeout_s: f64,
    /// snapshot the full run state every this many rounds (0 = off)
    pub checkpoint_every: usize,
    /// directory checkpoints are written to (atomic write-then-rename)
    pub checkpoint_dir: String,
    /// retain only the newest this-many checkpoints (older ones pruned)
    pub checkpoint_keep: usize,
    /// resume from this checkpoint file ("" = fresh run)
    pub resume: String,
}

impl TrainConfig {
    /// Scenario defaults per preset. Scales (K, T, n) are CPU-feasible
    /// stand-ins for the paper's (30/50/100 devices, 200/100/40 rounds);
    /// paper scales remain reachable via overrides (DESIGN.md §3).
    pub fn for_preset(preset: &str) -> TrainConfig {
        let (devices, rounds, partition, lr, n_train, n_test) = match preset {
            "mnist" => (8, 12, PartitionKind::LabelShards, 1e-3, 4096, 512),
            "cifar" => (8, 10, PartitionKind::Dirichlet, 1e-3, 2048, 256),
            "celeba" => (10, 8, PartitionKind::Writers, 1e-3, 2048, 256),
            // tiny: higher lr — the small native MLP learns in a handful of
            // ADAM steps, which is what the integration tests exercise
            _ => (4, 6, PartitionKind::LabelShards, 1e-2, 512, 64),
        };
        TrainConfig {
            preset: preset.to_string(),
            backend: BackendKind::default(),
            artifacts_dir: "artifacts".to_string(),
            devices,
            rounds,
            partition,
            seed: 0,
            lr,
            up_bits_per_entry: 32.0,
            down_bits_per_entry: 32.0,
            scheme: CodecSpec::vanilla(),
            q_ep: 200,
            noise_seed: 0x5EED,
            n_train,
            n_test,
            eval_every: 0,
            link_capacity_bps: 10e6,
            link_latency_s: 0.0,
            metrics_path: String::new(),
            threads: 0,
            simd: "auto".to_string(),
            staleness: 0,
            concurrent_devices: 0,
            per_device_opt: false,
            transport: TransportKind::InProc,
            listen: "127.0.0.1:0".to_string(),
            devices_remote: 0,
            fading_sigma: 0.0,
            scenario: ScenarioSpec::default(),
            rpc_deadline_s: 0.0,
            retry_base_ms: 10,
            retry_cap_ms: 500,
            retry_deadline_s: 15.0,
            liveness_timeout_s: 0.0,
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".to_string(),
            checkpoint_keep: 3,
            resume: String::new(),
        }
    }

    /// Number of scheduler worker threads a run will actually use
    /// (resolves the `concurrent_devices = 0` auto rule and clamps to K).
    pub fn resolved_concurrency(&self) -> usize {
        let want = match self.concurrent_devices {
            0 if self.staleness == 0 => 1,
            0 => self.devices,
            n => n,
        };
        want.clamp(1, self.devices.max(1))
    }

    /// Apply `--key value` CLI overrides. Errors (unknown scheme, backend,
    /// partition, malformed spec) are returned for the CLI to print.
    pub fn apply_overrides(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("backend") {
            self.backend = match BackendKind::parse(v) {
                Ok(b) => b,
                Err(e) => bail!("{e}"),
            };
        }
        if let Some(v) = args.get("artifacts") {
            self.artifacts_dir = v.to_string();
        }
        self.devices = args.get_usize("devices", self.devices);
        self.rounds = args.get_usize("rounds", self.rounds);
        self.seed = args.get_u64("seed", self.seed);
        self.lr = args.get_f64("lr", self.lr as f64) as f32;
        self.up_bits_per_entry = args.get_f64("up-bpe", self.up_bits_per_entry);
        self.down_bits_per_entry = args.get_f64("down-bpe", self.down_bits_per_entry);
        self.q_ep = args.get_u64("q-ep", self.q_ep);
        self.noise_seed = args.get_u64("noise-seed", self.noise_seed);
        self.n_train = args.get_usize("n-train", self.n_train);
        self.n_test = args.get_usize("n-test", self.n_test);
        self.eval_every = args.get_usize("eval-every", self.eval_every);
        self.link_capacity_bps = args.get_f64("capacity-bps", self.link_capacity_bps);
        self.threads = args.get_usize("threads", self.threads);
        // only an explicit flag touches the global dispatch mode — the
        // default must not clobber an SPLITFC_SIMD env resolution
        if let Some(v) = args.get("simd") {
            if let Err(e) = crate::util::simd::configure(v) {
                bail!("{e}");
            }
            self.simd = v.to_string();
        }
        self.staleness = args.get_usize("staleness", self.staleness);
        self.concurrent_devices =
            args.get_usize("concurrent-devices", self.concurrent_devices);
        if args.has_flag("per-device-opt") {
            self.per_device_opt = true;
        }
        if let Some(v) = args.get("transport") {
            self.transport = TransportKind::parse(v)?;
        }
        if let Some(v) = args.get("listen") {
            self.listen = v.to_string();
        }
        self.devices_remote = args.get_usize("devices-remote", self.devices_remote);
        self.fading_sigma = args.get_f64("fading-sigma", self.fading_sigma);
        if let Some(v) = args.get("scenario") {
            self.scenario = ScenarioSpec::parse(v)?;
        }
        self.rpc_deadline_s = args.get_f64("rpc-deadline-s", self.rpc_deadline_s);
        self.retry_base_ms = args.get_u64("retry-base-ms", self.retry_base_ms);
        self.retry_cap_ms = args.get_u64("retry-cap-ms", self.retry_cap_ms);
        self.retry_deadline_s = args.get_f64("retry-deadline-s", self.retry_deadline_s);
        self.liveness_timeout_s =
            args.get_f64("liveness-timeout-s", self.liveness_timeout_s);
        self.checkpoint_every = args.get_usize("checkpoint-every", self.checkpoint_every);
        if let Some(v) = args.get("checkpoint-dir") {
            self.checkpoint_dir = v.to_string();
        }
        self.checkpoint_keep = args.get_usize("checkpoint-keep", self.checkpoint_keep);
        if let Some(v) = args.get("resume") {
            self.resume = v.to_string();
        }
        // deprecated spelling of `--scenario "cut[dev=K,send=N]"`; kept for
        // script compatibility, now a comma list of device:send pairs that
        // appends to whatever --scenario already configured
        if let Some(v) = args.get("chaos-drop") {
            for pair in v.split(',') {
                let (k, n) = pair.split_once(':').ok_or_else(|| {
                    crate::err!("--chaos-drop wants device:send, got {pair:?}")
                })?;
                let k: usize = k
                    .parse()
                    .map_err(|_| crate::err!("--chaos-drop device {k:?} not a number"))?;
                let n: u64 = n
                    .parse()
                    .map_err(|_| crate::err!("--chaos-drop send {n:?} not a number"))?;
                self.scenario.push_cut(k, n);
            }
        }
        if let Some(v) = args.get("metrics") {
            self.metrics_path = v.to_string();
        }
        if let Some(v) = args.get("partition") {
            self.partition = match v {
                "shards" => PartitionKind::LabelShards,
                "dirichlet" => PartitionKind::Dirichlet,
                "writers" => PartitionKind::Writers,
                other => bail!("unknown partition {other:?} (shards|dirichlet|writers)"),
            };
        }
        if let Some(s) = args.get("scheme") {
            self.scheme = parse_scheme(s, args.get_f64("r", 16.0))?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("preset", Json::str(self.preset.clone())),
            ("backend", Json::str(self.backend.name())),
            ("devices", Json::num(self.devices as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("up_bpe", Json::num(self.up_bits_per_entry)),
            ("down_bpe", Json::num(self.down_bits_per_entry)),
            ("scheme", Json::str(self.scheme.to_string())),
            // fully-resolved codec name: alias defaults (e.g. the R=1 pin of
            // splitfc-quant-only) come from the builder, so this — not the
            // raw spec — is the reproducibility-grade provenance record
            ("codec", Json::str(self.scheme.canonical_name())),
            ("q_ep", Json::num(self.q_ep as f64)),
            ("noise_seed", Json::num(self.noise_seed as f64)),
            ("n_train", Json::num(self.n_train as f64)),
            ("n_test", Json::num(self.n_test as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("simd", Json::str(self.simd.clone())),
            ("staleness", Json::num(self.staleness as f64)),
            ("concurrent_devices", Json::num(self.concurrent_devices as f64)),
            ("per_device_opt", Json::Bool(self.per_device_opt)),
            ("transport", Json::str(self.transport.name())),
            ("devices_remote", Json::num(self.devices_remote as f64)),
            ("fading_sigma", Json::num(self.fading_sigma)),
            ("scenario", Json::str(self.scenario.to_string())),
            ("rpc_deadline_s", Json::num(self.rpc_deadline_s)),
            ("liveness_timeout_s", Json::num(self.liveness_timeout_s)),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            ("checkpoint_dir", Json::str(self.checkpoint_dir.clone())),
            ("resume", Json::str(self.resume.clone())),
        ])
    }

    /// FNV-1a digest of every trajectory-critical config field: two runs
    /// with equal fingerprints follow byte-identical trajectories (at
    /// staleness 0, where the shared Algorithm-1 stream rules), so a
    /// checkpoint refuses to resume under a config whose fingerprint
    /// differs. Knobs that only change speed, transport, or output plumbing
    /// — threads, simd, concurrency, transport/listen, metrics path, eval
    /// and checkpoint cadence, retry/liveness timing, link capacity/fading
    /// (modeled time, never payload bytes) — are deliberately excluded.
    pub fn trajectory_fingerprint(&self) -> u64 {
        fn eat(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x100_0000_01b3);
            }
            // field separator so adjacent fields cannot alias
            h ^= 0x1F;
            h.wrapping_mul(0x100_0000_01b3)
        }
        let partition: u8 = match self.partition {
            PartitionKind::LabelShards => 0,
            PartitionKind::Dirichlet => 1,
            PartitionKind::Writers => 2,
        };
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        h = eat(h, self.preset.as_bytes());
        h = eat(h, self.backend.name().as_bytes());
        h = eat(h, &(self.devices as u64).to_le_bytes());
        h = eat(h, &(self.rounds as u64).to_le_bytes());
        h = eat(h, &[partition]);
        h = eat(h, &self.seed.to_le_bytes());
        h = eat(h, &self.lr.to_bits().to_le_bytes());
        h = eat(h, &self.up_bits_per_entry.to_bits().to_le_bytes());
        h = eat(h, &self.down_bits_per_entry.to_bits().to_le_bytes());
        h = eat(h, self.scheme.canonical_name().as_bytes());
        h = eat(h, &self.q_ep.to_le_bytes());
        h = eat(h, &self.noise_seed.to_le_bytes());
        h = eat(h, &(self.n_train as u64).to_le_bytes());
        h = eat(h, &(self.n_test as u64).to_le_bytes());
        h = eat(h, &(self.staleness as u64).to_le_bytes());
        h = eat(h, &[self.per_device_opt as u8]);
        h = eat(h, self.scenario.to_string().as_bytes());
        h
    }
}

/// Parse a scheme spec (a Table-I-III row name or a bracketed
/// `splitfc[ad,R=8,fwq]`-style spec) into a validated [`CodecSpec`].
///
/// Unknown or malformed specs return an error listing every registered
/// codec name; the spec's codec is built once here so argument errors
/// surface at config time, not mid-training.
pub fn parse_scheme(name: &str, r: f64) -> Result<CodecSpec> {
    let spec = CodecSpec::parse_with_r(name, r)?;
    ensure!(
        is_registered(&spec.base),
        "unknown scheme {:?}; registered schemes: {}",
        spec.base,
        registered_names().join(", ")
    );
    // validate the full spec (bracket args) eagerly
    let _ = spec.build()?;
    Ok(spec)
}

/// The framework lineup of Table I (uplink compression comparison).
pub fn table1_frameworks() -> Vec<&'static str> {
    vec![
        "splitfc",
        "fedlite",
        "randtops",
        "tops",
        "splitfc-ad+pq",
        "splitfc-ad+eq",
        "splitfc-ad+nq",
        "tops+pq",
        "tops+eq",
        "tops+nq",
    ]
}

/// Table II lineup (uplink + downlink compression).
pub fn table2_frameworks() -> Vec<&'static str> {
    vec![
        "splitfc",
        "splitfc-ad+pq",
        "splitfc-ad+eq",
        "splitfc-ad+nq",
        "tops+pq",
        "tops+eq",
        "tops+nq",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn preset_defaults() {
        let c = TrainConfig::for_preset("mnist");
        assert_eq!(c.partition, PartitionKind::LabelShards);
        assert_eq!(c.q_ep, 200);
        assert_eq!(c.noise_seed, 0x5EED);
        assert_eq!(c.scheme, CodecSpec::vanilla());
        assert_eq!(TrainConfig::for_preset("cifar").partition, PartitionKind::Dirichlet);
        assert_eq!(TrainConfig::for_preset("celeba").partition, PartitionKind::Writers);
    }

    #[test]
    fn overrides_apply() {
        let mut c = TrainConfig::for_preset("tiny");
        c.apply_overrides(&args(
            "x --rounds 3 --devices 2 --scheme splitfc --r 8 --up-bpe 0.2 --threads 3",
        ))
        .unwrap();
        assert_eq!(c.rounds, 3);
        assert_eq!(c.devices, 2);
        assert_eq!(c.up_bits_per_entry, 0.2);
        assert_eq!(c.scheme, parse_scheme("splitfc", 8.0).unwrap());
        assert_eq!(c.scheme.r, 8.0);
        assert_eq!(c.threads, 3);
    }

    #[test]
    fn q_ep_and_noise_seed_flags_plumb_through() {
        let mut c = TrainConfig::for_preset("tiny");
        c.apply_overrides(&args("x --q-ep 64 --noise-seed 12345")).unwrap();
        assert_eq!(c.q_ep, 64);
        assert_eq!(c.noise_seed, 12345);
        let j = c.to_json();
        assert_eq!(j.req("q_ep").as_usize(), Some(64));
        assert_eq!(j.req("noise_seed").as_usize(), Some(12345));
    }

    #[test]
    fn simd_flag_plumbs_through() {
        let mut c = TrainConfig::for_preset("tiny");
        assert_eq!(c.simd, "auto");
        // pin, then restore auto — the knob mutates process-global dispatch
        c.apply_overrides(&args("x --simd off")).unwrap();
        assert_eq!(c.simd, "off");
        assert_eq!(crate::util::simd::mode(), crate::util::simd::SimdMode::Off);
        assert_eq!(c.to_json().req("simd").as_str(), Some("off"));
        c.apply_overrides(&args("x --simd auto")).unwrap();
        assert_eq!(
            crate::util::simd::mode() == crate::util::simd::SimdMode::Avx2,
            crate::util::simd::avx2_available()
        );
        assert!(c.apply_overrides(&args("x --simd sse9")).is_err());
    }

    #[test]
    fn bracketed_spec_overrides_parse() {
        let mut c = TrainConfig::for_preset("tiny");
        c.apply_overrides(&args("x --scheme splitfc[det,R=4,fixedQ8]")).unwrap();
        assert_eq!(c.scheme.base, "splitfc");
        assert!(c.scheme.has("det"));
        assert_eq!(c.scheme.get("R"), Some("4"));
        let codec = c.scheme.build().unwrap();
        assert_eq!(codec.name(), "splitfc[det,R=4,fixedQ8]");
        // the recorded codec name is the fully-resolved one (bracketed R=
        // wins over the CLI default)
        assert_eq!(c.to_json().req("codec").as_str(), Some("splitfc[det,R=4,fixedQ8]"));
    }

    #[test]
    fn recorded_codec_name_resolves_alias_defaults() {
        // splitfc-quant-only pins R=1 in its builder regardless of --r; the
        // metadata must reflect the codec that actually runs
        let mut c = TrainConfig::for_preset("tiny");
        c.apply_overrides(&args("x --scheme splitfc-quant-only --r 16")).unwrap();
        assert_eq!(
            c.to_json().req("codec").as_str(),
            Some("splitfc[none,R=1,fwq]")
        );
        // canonical names paste straight back into --scheme
        let name = c.scheme.canonical_name();
        let rebuilt = parse_scheme(&name, 16.0).unwrap().build().unwrap();
        assert_eq!(rebuilt.name(), name);
    }

    #[test]
    fn scheduler_overrides_and_auto_concurrency() {
        let mut c = TrainConfig::for_preset("tiny");
        assert_eq!((c.staleness, c.concurrent_devices), (0, 0));
        assert!(!c.per_device_opt);
        // auto: sequential at S=0, one worker per device otherwise
        assert_eq!(c.resolved_concurrency(), 1);
        c.staleness = 2;
        assert_eq!(c.resolved_concurrency(), c.devices);
        c.apply_overrides(&args("x --staleness 1 --concurrent-devices 3 --per-device-opt"))
            .unwrap();
        assert_eq!(c.staleness, 1);
        assert_eq!(c.concurrent_devices, 3);
        assert!(c.per_device_opt);
        assert_eq!(c.resolved_concurrency(), 3);
        // explicit request above K clamps to K
        c.concurrent_devices = 64;
        assert_eq!(c.resolved_concurrency(), c.devices);
    }

    #[test]
    fn transport_flags_plumb_through() {
        let mut c = TrainConfig::for_preset("tiny");
        assert_eq!(c.transport, TransportKind::InProc);
        assert_eq!(c.listen, "127.0.0.1:0");
        c.apply_overrides(&args(
            "x --transport tcp --listen 127.0.0.1:7777 --devices-remote 2 \
             --fading-sigma 0.5 --chaos-drop 1:13",
        ))
        .unwrap();
        assert_eq!(c.transport, TransportKind::Tcp);
        assert_eq!(c.listen, "127.0.0.1:7777");
        assert_eq!(c.devices_remote, 2);
        assert_eq!(c.fading_sigma, 0.5);
        // the deprecated --chaos-drop spelling routes into the scenario
        assert_eq!(c.scenario.to_string(), "cut[dev=1,send=13]");
        let j = c.to_json();
        assert_eq!(j.req("transport").as_str(), Some("tcp"));
        assert_eq!(j.req("devices_remote").as_usize(), Some(2));
        assert_eq!(j.req("scenario").as_str(), Some("cut[dev=1,send=13]"));
        assert!(c.apply_overrides(&args("x --transport udp")).is_err());
        assert!(c.apply_overrides(&args("x --chaos-drop nope")).is_err());
        assert!(c.apply_overrides(&args("x --chaos-drop a:7")).is_err());
    }

    #[test]
    fn scenario_flags_plumb_through() {
        let mut c = TrainConfig::for_preset("tiny");
        assert!(c.scenario.is_empty());
        assert_eq!(c.rpc_deadline_s, 0.0);
        assert_eq!(c.liveness_timeout_s, 0.0);
        assert_eq!((c.retry_base_ms, c.retry_cap_ms), (10, 500));
        assert_eq!(c.retry_deadline_s, 15.0);
        c.apply_overrides(&args(
            "x --scenario seed=7,straggler[dev=2,slow=8x],dropout[p=0.05,rejoin=2r] \
             --rpc-deadline-s 2.5 --retry-base-ms 5 --retry-cap-ms 100 \
             --retry-deadline-s 4 --liveness-timeout-s 6",
        ))
        .unwrap();
        assert_eq!(c.scenario.seed, Some(7));
        assert_eq!(c.scenario.clauses.len(), 2);
        assert_eq!(c.rpc_deadline_s, 2.5);
        assert_eq!((c.retry_base_ms, c.retry_cap_ms), (5, 100));
        assert_eq!(c.retry_deadline_s, 4.0);
        assert_eq!(c.liveness_timeout_s, 6.0);
        // --chaos-drop comma lists append cut clauses after the spec's own
        c.apply_overrides(&args("x --chaos-drop 0:6,1:9")).unwrap();
        assert_eq!(
            c.scenario.to_string(),
            "seed=7,straggler[dev=2,slow=8x],dropout[p=0.05,rejoin=2r],\
             cut[dev=0,send=6],cut[dev=1,send=9]"
        );
        assert!(c.apply_overrides(&args("x --scenario straggler[bogus=1]")).is_err());
    }

    #[test]
    fn checkpoint_flags_plumb_through() {
        let mut c = TrainConfig::for_preset("tiny");
        assert_eq!(c.checkpoint_every, 0);
        assert_eq!(c.checkpoint_dir, "checkpoints");
        assert_eq!(c.checkpoint_keep, 3);
        assert!(c.resume.is_empty());
        c.apply_overrides(&args(
            "x --checkpoint-every 5 --checkpoint-dir snaps --checkpoint-keep 2 \
             --resume snaps/ckpt-r00005.splitfc",
        ))
        .unwrap();
        assert_eq!(c.checkpoint_every, 5);
        assert_eq!(c.checkpoint_dir, "snaps");
        assert_eq!(c.checkpoint_keep, 2);
        assert_eq!(c.resume, "snaps/ckpt-r00005.splitfc");
        let j = c.to_json();
        assert_eq!(j.req("checkpoint_every").as_usize(), Some(5));
        assert_eq!(j.req("checkpoint_dir").as_str(), Some("snaps"));
        assert_eq!(j.req("resume").as_str(), Some("snaps/ckpt-r00005.splitfc"));
    }

    #[test]
    fn fingerprint_tracks_trajectory_critical_fields_only() {
        let base = TrainConfig::for_preset("tiny");
        let fp = base.trajectory_fingerprint();
        // deterministic
        assert_eq!(fp, TrainConfig::for_preset("tiny").trajectory_fingerprint());
        // every trajectory-critical knob moves it
        for mutate in [
            (|c: &mut TrainConfig| c.seed = 99) as fn(&mut TrainConfig),
            |c| c.devices += 1,
            |c| c.rounds += 1,
            |c| c.lr *= 2.0,
            |c| c.up_bits_per_entry = 4.0,
            |c| c.n_train += 1,
            |c| c.per_device_opt = true,
            |c| c.staleness = 1,
            |c| c.partition = PartitionKind::Writers,
            |c| c.scheme = parse_scheme("splitfc", 8.0).unwrap(),
        ] {
            let mut c = TrainConfig::for_preset("tiny");
            mutate(&mut c);
            assert_ne!(c.trajectory_fingerprint(), fp, "mutation must change fingerprint");
        }
        // speed/plumbing knobs must NOT move it — a resumed run may change
        // them freely
        let mut c = TrainConfig::for_preset("tiny");
        c.threads = 7;
        c.eval_every = 2;
        c.metrics_path = "m.jsonl".into();
        c.transport = TransportKind::Tcp;
        c.checkpoint_every = 5;
        c.resume = "x".into();
        c.liveness_timeout_s = 9.0;
        c.link_capacity_bps = 1e3;
        assert_eq!(c.trajectory_fingerprint(), fp);
    }

    #[test]
    fn all_table_frameworks_parse() {
        for name in table1_frameworks().iter().chain(table2_frameworks().iter()) {
            parse_scheme(name, 16.0).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        for extra in ["vanilla", "splitfc-ad", "splitfc-rand", "splitfc-det",
                      "splitfc-quant-only", "splitfc-no-mean"] {
            parse_scheme(extra, 8.0).unwrap_or_else(|e| panic!("{extra}: {e}"));
        }
    }

    #[test]
    fn unknown_scheme_errors_listing_choices() {
        let err = parse_scheme("nope", 1.0).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown scheme"), "{msg}");
        assert!(msg.contains("splitfc"), "should list registered names: {msg}");
        assert!(msg.contains("fedlite"), "{msg}");
        // malformed bracket args of a known codec also error cleanly
        assert!(parse_scheme("splitfc[bogus-arg]", 1.0).is_err());
        // and the CLI path surfaces it as an Err, not a panic
        let mut c = TrainConfig::for_preset("tiny");
        assert!(c.apply_overrides(&args("x --scheme nope")).is_err());
    }

    #[test]
    fn config_json_roundtrip_fields() {
        let c = TrainConfig::for_preset("mnist");
        let j = c.to_json();
        assert_eq!(j.req("preset").as_str(), Some("mnist"));
        assert_eq!(j.req("devices").as_usize(), Some(8));
        assert_eq!(j.req("backend").as_str(), Some("native"));
        assert_eq!(j.req("scheme").as_str(), Some("vanilla"));
    }

    #[test]
    fn backend_override_applies() {
        let mut c = TrainConfig::for_preset("tiny");
        assert_eq!(c.backend, BackendKind::Native);
        c.apply_overrides(&args("x --backend pjrt")).unwrap();
        assert_eq!(c.backend, BackendKind::Pjrt);
    }
}
