//! Optimizers (Sec. III-A): SGD (eq. 6) and ADAM [42].
//!
//! Per the paper's storage model, the PS keeps the ADAM first/second moments
//! for the *device-side* model too, so devices stay stateless between their
//! round-robin turns ("the PS can update the device-side model if it stores
//! the first and second raw moments of the ADAM optimizer").

pub mod adam;
pub mod sgd;

pub use adam::{Adam, AdamState};
pub use sgd::Sgd;

/// A stateful first-order optimizer over a flat f32 parameter vector.
pub trait Optimizer {
    fn step(&mut self, params: &mut [f32], grads: &[f32]);
    fn name(&self) -> &'static str;
}
