//! ADAM [42] with bias correction — the optimizer the paper uses for all
//! three scenarios (initial lr 1e-3 for MNIST, 1e-4 for CIFAR-100/CelebA).

use super::Optimizer;
use crate::util::error::Result;

/// The serializable ADAM state: step count + both raw moment vectors (the
/// hyperparameters travel in the run config, not the snapshot).
#[derive(Debug, Clone, PartialEq)]
pub struct AdamState {
    pub t: u64,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    pub fn new(lr: f32, n_params: usize) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
        }
    }

    pub fn t(&self) -> u64 {
        self.t
    }

    /// Raw moments — what the PS stores to update the device-side model
    /// without shipping optimizer state (Sec. III-A).
    pub fn moments(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    /// Snapshot the full optimizer state for checkpointing.
    pub fn export_state(&self) -> AdamState {
        AdamState { t: self.t, m: self.m.clone(), v: self.v.clone() }
    }

    /// Overwrite this optimizer's state from a snapshot, validating that
    /// the moment vectors were sized for the same model.
    pub fn restore_state(&mut self, st: &AdamState) -> Result<()> {
        crate::ensure!(
            st.m.len() == self.m.len() && st.v.len() == self.v.len(),
            "adam snapshot sized for {}/{} params, optimizer has {}",
            st.m.len(),
            st.v.len(),
            self.m.len()
        );
        self.t = st.t;
        self.m.copy_from_slice(&st.m);
        self.v.copy_from_slice(&st.v);
        Ok(())
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len(), "Adam sized for different model");
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        // With zero-init moments, step 1 moves each param by ~lr*sign(g).
        let mut opt = Adam::new(0.001, 3);
        let mut w = vec![0.0f32; 3];
        opt.step(&mut w, &[1.0, -2.5, 100.0]);
        for (i, &wi) in w.iter().enumerate() {
            let expected = if i == 1 { 0.001 } else { -0.001 };
            assert!((wi - expected).abs() < 1e-6, "w[{i}]={wi}");
        }
    }

    #[test]
    fn matches_hand_computed_two_steps() {
        let mut opt = Adam::new(0.1, 1);
        let mut w = vec![1.0f32];
        let g = 0.5f32;
        // step 1
        opt.step(&mut w, &[g]);
        let m1 = 0.1 * g / (1.0 - 0.9f32);
        let v1 = 0.001 * g * g / (1.0 - 0.999f32);
        let w1 = 1.0 - 0.1 * m1 / (v1.sqrt() + 1e-8);
        assert!((w[0] - w1).abs() < 1e-5, "{} vs {}", w[0], w1);
        // step 2, same grad
        opt.step(&mut w, &[g]);
        let m_raw = 0.1 * g + 0.9 * 0.1 * g; // beta1*m1_raw + (1-b1)g
        let v_raw = 0.001 * g * g + 0.999 * 0.001 * g * g;
        let mhat = m_raw / (1.0 - 0.9f32.powi(2));
        let vhat = v_raw / (1.0 - 0.999f32.powi(2));
        let w2 = w1 - 0.1 * mhat / (vhat.sqrt() + 1e-8);
        assert!((w[0] - w2).abs() < 1e-5, "{} vs {}", w[0], w2);
    }

    #[test]
    fn moments_accessible_and_sized() {
        let mut opt = Adam::new(0.01, 4);
        opt.step(&mut vec![0.0; 4], &[1.0; 4]);
        let (m, v) = opt.moments();
        assert_eq!(m.len(), 4);
        assert!(m.iter().all(|&x| x > 0.0));
        assert!(v.iter().all(|&x| x > 0.0));
        assert_eq!(opt.t(), 1);
    }

    #[test]
    fn state_roundtrip_continues_identically() {
        let mut a = Adam::new(0.01, 4);
        let mut wa = vec![0.0f32; 4];
        a.step(&mut wa, &[1.0, -1.0, 0.5, 2.0]);
        let st = a.export_state();
        let mut b = Adam::new(0.01, 4);
        b.restore_state(&st).unwrap();
        let mut wb = wa.clone();
        a.step(&mut wa, &[0.25; 4]);
        b.step(&mut wb, &[0.25; 4]);
        assert_eq!(wa, wb);
        assert_eq!(a.t(), b.t());
        // a snapshot from a differently-sized model is rejected
        assert!(Adam::new(0.01, 3).restore_state(&st).is_err());
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(w) = (w-3)^2 ; grad = 2(w-3)
        let mut opt = Adam::new(0.1, 1);
        let mut w = vec![0.0f32];
        for _ in 0..500 {
            let g = 2.0 * (w[0] - 3.0);
            opt.step(&mut w, &[g]);
        }
        assert!((w[0] - 3.0).abs() < 0.05, "w={}", w[0]);
    }
}
