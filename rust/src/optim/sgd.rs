//! Plain SGD: w <- w - eta * g (paper eq. 6).

use super::Optimizer;

#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Sgd {
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        for (w, &g) in params.iter_mut().zip(grads) {
            *w -= self.lr * g;
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_matches_eq6() {
        let mut opt = Sgd::new(0.1);
        let mut w = vec![1.0f32, -2.0, 0.5];
        opt.step(&mut w, &[10.0, -10.0, 0.0]);
        assert_eq!(w, vec![0.0, -1.0, 0.5]);
    }

    #[test]
    fn zero_grad_is_identity() {
        let mut opt = Sgd::new(0.5);
        let mut w = vec![3.0f32; 8];
        opt.step(&mut w, &vec![0.0; 8]);
        assert_eq!(w, vec![3.0f32; 8]);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        Sgd::new(0.1).step(&mut [0.0], &[0.0, 0.0]);
    }
}
