//! Wire hot-path microbenchmarks → BENCH_wire.json.
//!
//! Three questions, answered on the paper-scale `B=64, D̄=8192` FWQ frame
//! (the Sec. VII regime, the same frame `BENCH_fwq.json` tracks):
//!
//! 1. **bitio kernels** — serializing/deserializing the exact bit profile of
//!    that frame (flags, radix-packed endpoint/mean/entry symbols, blob
//!    embed) through the word-level `BitWriter`/`BitReader` vs the original
//!    per-bit `BitWriterRef`/`BitReaderRef` oracles. This is the layer the
//!    zero-allocation rewrite targets; the acceptance gate is ≥ 3× on the
//!    write side.
//! 2. **codec sessions** — full `splitfc[ad,R=8,fwq]` uplink encode/decode
//!    ns/op through the fused path, serial and threaded.
//! 3. **allocations/step** — cold first step vs steady state under the
//!    counting allocator (`--features alloc-count`); steady state must be
//!    **zero** or the bench exits non-zero (the CI gate).
//!
//! `-- --quick` shortens runs for CI smoke; `THREADS=<n>` / `-- --threads n`
//! sizes the pool for the threaded rows.

use splitfc::bench::Bencher;
use splitfc::bitio::{BitReader, BitReaderRef, BitWriter, BitWriterRef};
use splitfc::compression::{
    fwq_encode, Codec, CodecParams, CodecSpec, FwqConfig, Reclaim, SigmaStats,
};
use splitfc::tensor::{column_stats, normalized_sigma};
use splitfc::testkit::hetero_matrix;
use splitfc::util::{alloc_count, par, Args, Json, Rng};

const B: usize = 64;
const DBAR: usize = 8192;
const BPE: f64 = 0.2;

/// The bit-level profile of a real FWQ frame: symbol streams with the sizes
/// and radices an actual encode of the B×D̄ matrix produces.
struct FrameShape {
    delta: Vec<u64>,    // D̄ dropout flag bits
    flags: Vec<u64>,    // D̂ two-stage flag bits
    ep_syms: Vec<u64>,  // 2M endpoint codes, radix Q_ep
    q_ep: u64,
    mean_syms: Vec<u64>, // D̂-M mean codes, radix Q0
    q0: u64,
    col_syms: Vec<Vec<u64>>, // M columns × B entry codes
    q_col: u64,
    blob: Vec<u8>, // the embedded sub-stream bytes (blob fast-path volume)
}

impl FrameShape {
    /// Derive the shape from an actual paper-scale encode (M*, Q0, and the
    /// per-column level mass all come from the real frame).
    fn paper_scale() -> FrameShape {
        let a = hetero_matrix(B, DBAR, 42);
        let cfg = FwqConfig::paper_default(B, BPE * (B * DBAR) as f64);
        let (bytes, _bits, info) = fwq_encode(&a, &cfg);
        let m = info.m_star.max(1);
        let n_mean = DBAR - m;
        let q0 = info.q0.unwrap_or(2).max(2);
        // back out the average per-column entry level from eq.-17 accounting
        let lg_ep = 200f64.log2();
        let entry_bits = (info.nominal_bits
            - 2.0 * m as f64 * lg_ep
            - DBAR as f64
            - 128.0
            - n_mean as f64 * (q0 as f64).log2())
        .max(0.0);
        let bits_per_sym = entry_bits / (m as f64 * B as f64);
        let q_col = (2f64.powf(bits_per_sym).round() as u64).clamp(2, 1 << 16);

        let mut rng = Rng::new(7);
        FrameShape {
            delta: (0..DBAR).map(|_| (rng.next_u64() & 1)).collect(),
            flags: (0..DBAR).map(|i| ((i < m) as u64)).collect(),
            ep_syms: (0..2 * m).map(|_| rng.next_u64() % 200).collect(),
            q_ep: 200,
            mean_syms: (0..n_mean).map(|_| rng.next_u64() % q0).collect(),
            q0,
            col_syms: (0..m)
                .map(|_| (0..B).map(|_| rng.next_u64() % q_col).collect())
                .collect(),
            q_col,
            blob: bytes,
        }
    }
}

/// Writer facade so the same frame-emission code drives both the word-level
/// writer and the per-bit reference oracle.
trait Put {
    fn bits(&mut self, v: u64, n: u32);
    fn radix(&mut self, syms: &[u64], q: u64);
    fn bytes(&mut self, b: &[u8]);
    fn blen(&self) -> u64;
}

impl Put for BitWriter {
    fn bits(&mut self, v: u64, n: u32) {
        self.write_bits(v, n)
    }
    fn radix(&mut self, syms: &[u64], q: u64) {
        self.write_radix(syms, q)
    }
    fn bytes(&mut self, b: &[u8]) {
        self.write_bytes(b)
    }
    fn blen(&self) -> u64 {
        self.bit_len()
    }
}

impl Put for BitWriterRef {
    fn bits(&mut self, v: u64, n: u32) {
        self.write_bits(v, n)
    }
    fn radix(&mut self, syms: &[u64], q: u64) {
        self.write_radix(syms, q)
    }
    fn bytes(&mut self, b: &[u8]) {
        self.write_bytes(b)
    }
    fn blen(&self) -> u64 {
        self.bit_len()
    }
}

fn emit_frame<W: Put>(w: &mut W, fr: &FrameShape) -> u64 {
    for &d in &fr.delta {
        w.bits(d, 1);
    }
    w.bits(fr.flags.len() as u64, 32);
    w.bits(fr.col_syms.len() as u64, 32);
    for _ in 0..4 {
        w.bits(0x3F80_0000, 32); // the 4 range f32s
    }
    for &f in &fr.flags {
        w.bits(f, 1);
    }
    w.radix(&fr.ep_syms, fr.q_ep);
    w.radix(&fr.mean_syms, fr.q0);
    for col in &fr.col_syms {
        w.radix(col, fr.q_col);
    }
    // blob embed: 40-bit length prefix + byte run (the bulk fast path)
    w.bits(fr.blob.len() as u64 * 8, 40);
    w.bytes(&fr.blob);
    w.blen()
}

fn read_frame_word(buf: &[u8], fr: &FrameShape, sink: &mut Vec<u8>) -> u64 {
    let mut r = BitReader::new(buf);
    let mut acc = 0u64;
    for _ in 0..fr.delta.len() {
        acc ^= r.read_bits(1);
    }
    acc ^= r.read_bits(32) + r.read_bits(32);
    for _ in 0..4 {
        acc ^= r.read_bits(32);
    }
    for _ in 0..fr.flags.len() {
        acc ^= r.read_bits(1);
    }
    acc ^= r.read_radix(fr.ep_syms.len(), fr.q_ep).last().copied().unwrap_or(0);
    acc ^= r.read_radix(fr.mean_syms.len(), fr.q0).last().copied().unwrap_or(0);
    for col in &fr.col_syms {
        acc ^= r.read_radix(col.len(), fr.q_col).last().copied().unwrap_or(0);
    }
    let nbits = r.read_bits(40);
    sink.clear();
    r.try_read_bytes_into((nbits / 8) as usize, sink).expect("blob");
    acc
}

fn read_frame_ref(buf: &[u8], fr: &FrameShape, sink: &mut Vec<u8>) -> u64 {
    let mut r = BitReaderRef::new(buf);
    let mut acc = 0u64;
    for _ in 0..fr.delta.len() {
        acc ^= r.read_bits(1);
    }
    acc ^= r.read_bits(32) + r.read_bits(32);
    for _ in 0..4 {
        acc ^= r.read_bits(32);
    }
    for _ in 0..fr.flags.len() {
        acc ^= r.read_bits(1);
    }
    acc ^= r.read_radix(fr.ep_syms.len(), fr.q_ep).last().copied().unwrap_or(0);
    acc ^= r.read_radix(fr.mean_syms.len(), fr.q0).last().copied().unwrap_or(0);
    for col in &fr.col_syms {
        acc ^= r.read_radix(col.len(), fr.q_col).last().copied().unwrap_or(0);
    }
    let nbits = r.read_bits(40);
    sink.clear();
    for _ in 0..(nbits / 8) {
        sink.push(r.read_bits(8) as u8);
    }
    acc
}

fn main() {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let threads_req = par::thread_request(args.get_usize("threads", 0));
    let bench = if quick { Bencher::quick() } else { Bencher::default() };

    println!("deriving the paper-scale frame shape (B={B}, D̄={DBAR}, {BPE} bpe)...");
    par::set_threads(1);
    let fr = FrameShape::paper_scale();
    println!(
        "  M*={}, Q0={}, Q_col={}, blob={} bytes",
        fr.col_syms.len(),
        fr.q0,
        fr.q_col,
        fr.blob.len()
    );

    // ---- 1. bitio kernels, write side ----
    let st_wref = bench.run("wire/write/ref(per-bit)", || {
        let mut w = BitWriterRef::new();
        emit_frame(&mut w, &fr)
    });
    println!("{}", st_wref.report());
    let mut reuse = Vec::new();
    let st_word = bench.run("wire/write/word-level", || {
        let mut w = BitWriter::from_buf(std::mem::take(&mut reuse));
        let bits = emit_frame(&mut w, &fr);
        reuse = w.into_bytes();
        bits
    });
    println!("{}", st_word.report());
    let write_speedup = st_wref.p50_s / st_word.p50_s;

    // parity of the two kernels on this stream
    let mut a = BitWriter::new();
    emit_frame(&mut a, &fr);
    let mut b = BitWriterRef::new();
    emit_frame(&mut b, &fr);
    let buf = a.into_bytes();
    assert_eq!(buf, b.into_bytes(), "word writer must match the oracle");

    // ---- 1b. bitio kernels, read side ----
    let mut sink = Vec::new();
    let st_rref = bench.run("wire/read/ref(per-bit)", || read_frame_ref(&buf, &fr, &mut sink));
    println!("{}", st_rref.report());
    let st_rword = bench.run("wire/read/word-level", || read_frame_word(&buf, &fr, &mut sink));
    println!("{}", st_rword.report());
    let read_speedup = st_rref.p50_s / st_rword.p50_s;
    println!(
        "\nbitio on the FWQ frame: write {write_speedup:.2}x, read {read_speedup:.2}x \
         (word-level vs per-bit reference)"
    );

    // ---- 2. full codec session, fused path ----
    let f = hetero_matrix(B, DBAR, 42);
    let stats = SigmaStats::new(normalized_sigma(&column_stats(&f), 64));
    let up = CodecParams::new(B, DBAR, BPE);
    let spec = CodecSpec::parse_with_r("splitfc", 8.0).expect("spec");
    let mut codec = spec.build().expect("build splitfc");
    let name = codec.name();

    par::set_threads(1);
    let mut rng = Rng::new(11);
    let st_enc1 = bench.run(&format!("codec/{name}/encode/threads=1"), || {
        let enc = codec.encode_uplink(&f, Some(&stats), &up, &mut rng).expect("encode");
        let bits = enc.frame.payload_bits;
        codec.reclaim(Reclaim::Uplink(enc));
        bits
    });
    println!("{}", st_enc1.report());

    par::set_threads(threads_req);
    let tn = par::threads();
    let st_encn = bench.run(&format!("codec/{name}/encode/threads={tn}"), || {
        let enc = codec.encode_uplink(&f, Some(&stats), &up, &mut rng).expect("encode");
        let bits = enc.frame.payload_bits;
        codec.reclaim(Reclaim::Uplink(enc));
        bits
    });
    println!("{}", st_encn.report());

    par::set_threads(1);
    let frame = codec.encode_uplink(&f, Some(&stats), &up, &mut rng).expect("encode").frame;
    let st_dec = bench.run(&format!("codec/{name}/decode/threads=1"), || {
        let dec = codec.decode_uplink(&frame, &up).expect("decode");
        let n = dec.kept.len();
        codec.reclaim(Reclaim::Decoded(dec));
        n
    });
    println!("{}", st_dec.report());

    // ---- 3. allocations per step (cold vs steady state) ----
    let down = CodecParams::new(B, DBAR, 2.0);
    let g = hetero_matrix(B, DBAR, 43);
    let step = |codec: &mut dyn splitfc::compression::Codec, rng: &mut Rng| {
        let enc = codec.encode_uplink(&f, Some(&stats), &up, rng).expect("encode");
        let dec = codec.decode_uplink(&enc.frame, &up).expect("decode");
        let dn = codec.encode_downlink(&g, &enc.mask, &down).expect("down encode");
        let gh = codec.decode_downlink(&dn.frame, &enc.mask, &down).expect("down decode");
        codec.reclaim(Reclaim::Decoded(dec));
        codec.reclaim(Reclaim::Grad(gh));
        codec.reclaim(Reclaim::Downlink(dn));
        codec.reclaim(Reclaim::Uplink(enc));
    };
    let mut fresh = spec.build().expect("build splitfc");
    let mut rng2 = Rng::new(23);
    let cold_before = alloc_count::allocations();
    step(fresh.as_mut(), &mut rng2);
    let cold_after = alloc_count::allocations();
    for _ in 0..4 {
        step(fresh.as_mut(), &mut rng2); // warm-up: pools reach their bounds
    }
    let steady_steps = if quick { 8 } else { 32 };
    let before = alloc_count::allocations();
    for _ in 0..steady_steps {
        step(fresh.as_mut(), &mut rng2);
    }
    let after = alloc_count::allocations();

    let (cold_allocs, steady_per_step, counting) = match (cold_before, cold_after, before, after)
    {
        (Some(c0), Some(c1), Some(s0), Some(s1)) => {
            (Some(c1 - c0), Some((s1 - s0) as f64 / steady_steps as f64), true)
        }
        _ => (None, None, false),
    };
    match (cold_allocs, steady_per_step) {
        (Some(cold), Some(steady)) => {
            println!(
                "\nallocations/step for {name}: {cold} cold (first step), {steady} steady state"
            );
        }
        _ => println!(
            "\nallocations/step: counting allocator disabled \
             (rebuild with --features alloc-count)"
        ),
    }

    // ---- record ----
    let j = Json::obj(vec![
        ("bench", Json::str("wire_hot_path")),
        ("batch", Json::num(B as f64)),
        ("dbar", Json::num(DBAR as f64)),
        ("bits_per_entry_budget", Json::num(BPE)),
        ("threads", Json::num(tn as f64)),
        (
            "bitio_write_ns_per_op",
            Json::obj(vec![
                ("ref_per_bit", Json::num(st_wref.p50_s * 1e9)),
                ("word_level", Json::num(st_word.p50_s * 1e9)),
                ("speedup", Json::num(write_speedup)),
            ]),
        ),
        (
            "bitio_read_ns_per_op",
            Json::obj(vec![
                ("ref_per_bit", Json::num(st_rref.p50_s * 1e9)),
                ("word_level", Json::num(st_rword.p50_s * 1e9)),
                ("speedup", Json::num(read_speedup)),
            ]),
        ),
        (
            "codec_ns_per_op",
            Json::obj(vec![
                ("encode_serial", Json::num(st_enc1.p50_s * 1e9)),
                ("encode_threaded", Json::num(st_encn.p50_s * 1e9)),
                ("decode_serial", Json::num(st_dec.p50_s * 1e9)),
            ]),
        ),
        (
            "allocs_per_step",
            Json::obj(vec![
                (
                    "cold_first_step",
                    cold_allocs.map(|v| Json::num(v as f64)).unwrap_or(Json::Null),
                ),
                (
                    "steady_state",
                    steady_per_step.map(Json::num).unwrap_or(Json::Null),
                ),
            ]),
        ),
        ("alloc_count_enabled", Json::Bool(counting)),
    ]);
    std::fs::write("BENCH_wire.json", j.to_string_pretty()).expect("write BENCH_wire.json");
    println!("[saved BENCH_wire.json]");

    // ---- gates ----
    if counting {
        let steady = steady_per_step.unwrap_or(f64::NAN);
        assert!(
            steady == 0.0,
            "steady-state wire path must be allocation-free: {steady} allocs/step"
        );
        println!("zero-allocation gate: OK");
    }
    // the PR's acceptance gate: the word-level writer must beat the per-bit
    // reference by >= 3x on this frame. A regression to below 3x is a CI
    // failure, not a warning — the margin in practice is far larger.
    assert!(
        write_speedup >= 3.0,
        "word-level write speedup {write_speedup:.2}x below the 3x acceptance gate"
    );
    println!("3x write-speedup gate: OK ({write_speedup:.2}x)");
}
