//! Checkpoint subsystem bench: snapshot cost on the training path (wall
//! overhead of `--checkpoint-every 1` vs no checkpointing), raw
//! encode/load throughput and file size of a real snapshot, and the
//! restart cost of `--resume` — plus a correctness probe (resume from the
//! mid-run snapshot must reproduce the uninterrupted deterministic step
//! fields exactly; the bench **fails** non-zero if it does not).
//!
//! Writes `BENCH_ckpt.json`; `-- --quick` shortens the run for CI.

use std::time::Instant;

use splitfc::checkpoint::Checkpoint;
use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::util::{par, Args, Json, Result};

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("splitfc_bench_ckpt_{tag}_{}", std::process::id()))
}

fn cfg_for(rounds: usize, metrics: &str, dir: &str, every: usize) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = rounds;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.eval_every = 0;
    cfg.seed = 11;
    cfg.scheme = parse_scheme("splitfc[ad,R=4,fwq,ef]", 4.0)?;
    cfg.up_bits_per_entry = 2.0;
    cfg.down_bits_per_entry = 4.0;
    cfg.metrics_path = metrics.to_string();
    cfg.checkpoint_every = every;
    cfg.checkpoint_dir = dir.to_string();
    cfg.checkpoint_keep = rounds.max(1);
    Ok(cfg)
}

/// Deterministic step fields of a metrics stream (wall-clock excluded).
fn step_fields(path: &std::path::Path) -> Result<Vec<String>> {
    const KEYS: [&str; 9] = [
        "t", "k", "g", "loss", "train_acc", "up_bits", "down_bits", "up_nominal",
        "down_nominal",
    ];
    let text =
        std::fs::read_to_string(path).map_err(|e| splitfc::err!("metrics {path:?}: {e}"))?;
    let mut rows = Vec::new();
    for line in text.lines() {
        let Ok(j) = Json::parse(line) else { continue };
        if j.get("g").is_none() {
            continue;
        }
        let mut fields = Vec::with_capacity(KEYS.len());
        for k in KEYS {
            let v = j
                .get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| splitfc::err!("step record missing {k:?}"))?;
            fields.push(format!("{k}={v:?}"));
        }
        rows.push(fields.join(" "));
    }
    Ok(rows)
}

fn timed_run(cfg: TrainConfig) -> Result<f64> {
    let t0 = Instant::now();
    let mut tr = Trainer::new(cfg)?;
    tr.run()?;
    drop(tr);
    Ok(t0.elapsed().as_secs_f64())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let inner_threads = par::thread_request(args.get_usize("threads", 1)).max(1);
    par::set_threads(inner_threads);
    let rounds = if quick { 4 } else { 10 };
    let iters = if quick { 20 } else { 100 };

    let ref_metrics = tmp_path("ref.jsonl");
    let live_metrics = tmp_path("live.jsonl");
    let dir = tmp_path("snaps");

    // 1. training-path overhead: snapshot EVERY round vs never
    let base_s = timed_run(cfg_for(rounds, ref_metrics.to_str().unwrap(), "", 0)?)?;
    let ckpt_s = timed_run(cfg_for(
        rounds,
        live_metrics.to_str().unwrap(),
        dir.to_str().unwrap(),
        1,
    )?)?;
    let per_snapshot_s = (ckpt_s - base_s).max(0.0) / rounds as f64;
    println!(
        "train {rounds}r: base {base_s:.3}s, ckpt-every-1 {ckpt_s:.3}s \
         -> {:.2} ms/snapshot",
        per_snapshot_s * 1e3
    );

    // 2. raw snapshot encode/load throughput + size
    let snap_path = dir.join(Checkpoint::file_name(rounds as u32 / 2));
    let file_len = std::fs::metadata(&snap_path)
        .map_err(|e| splitfc::err!("snapshot {snap_path:?}: {e}"))?
        .len();
    let ckpt = Checkpoint::load(&snap_path).map_err(|e| splitfc::err!("load: {e}"))?;
    let t0 = Instant::now();
    let mut encoded_len = 0usize;
    for _ in 0..iters {
        encoded_len = ckpt.encode().len();
    }
    let encode_s = t0.elapsed().as_secs_f64() / iters as f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        Checkpoint::load(&snap_path).map_err(|e| splitfc::err!("load: {e}"))?;
    }
    let load_s = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "snapshot: {file_len} bytes, encode {:.3} ms, load+verify {:.3} ms",
        encode_s * 1e3,
        load_s * 1e3
    );

    // 3. restart cost + the correctness probe: resume from mid-run, same
    // metrics file, stream must match the uninterrupted reference exactly
    let want = step_fields(&ref_metrics)?;
    let mut cfg = cfg_for(rounds, live_metrics.to_str().unwrap(), "", 0)?;
    cfg.resume = snap_path.to_str().unwrap().to_string();
    let t0 = Instant::now();
    let mut tr = Trainer::new(cfg)?;
    let restore_s = t0.elapsed().as_secs_f64();
    tr.run()?;
    drop(tr);
    let got = step_fields(&live_metrics)?;
    splitfc::ensure!(
        got == want,
        "resume probe: resumed stream diverged from the uninterrupted run \
         ({} vs {} steps)",
        got.len(),
        want.len()
    );
    println!(
        "resume: restore {:.1} ms, {} steps byte-identical after restart",
        restore_s * 1e3,
        got.len()
    );

    let j = Json::obj(vec![
        ("bench", Json::str("ckpt")),
        ("preset", Json::str("tiny")),
        ("devices", Json::num(4.0)),
        ("rounds", Json::num(rounds as f64)),
        ("inner_threads", Json::num(par::threads() as f64)),
        ("train_base_s", Json::num(base_s)),
        ("train_ckpt_every_1_s", Json::num(ckpt_s)),
        ("per_snapshot_s", Json::num(per_snapshot_s)),
        ("snapshot_bytes", Json::num(file_len as f64)),
        ("encoded_bytes", Json::num(encoded_len as f64)),
        ("encode_s", Json::num(encode_s)),
        ("load_verify_s", Json::num(load_s)),
        ("resume_restore_s", Json::num(restore_s)),
        ("resume_steps_identical", Json::num(want.len() as f64)),
    ]);
    std::fs::write("BENCH_ckpt.json", j.to_string_pretty()).expect("write BENCH_ckpt.json");
    println!("[saved BENCH_ckpt.json]");

    std::fs::remove_file(&ref_metrics).ok();
    std::fs::remove_file(&live_metrics).ok();
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
