//! End-to-end step latency: one full (t, k) protocol step (device_fwd ->
//! stats -> FWDP/FWQ -> server_fwd_bwd -> downlink -> device_bwd -> ADAM)
//! per preset and scheme, measured with `threads = 1` and with the
//! configured pool, plus a micro-comparison of the blocked matmul kernels
//! against the pre-blocking scalar references.
//!
//! Writes `BENCH_e2e.json` (per-config ns/op serial vs threaded + the
//! kernel micro numbers) — the e2e leg of the repo's perf trajectory.
//! `THREADS=<n>` / `-- --threads <n>` size the pool (0/unset = auto);
//! `-- --quick` shortens the run for CI smoke.

use splitfc::bench::Bencher;
use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::tensor::Matrix;
use splitfc::util::{par, Args, Json, Rng};

fn step_p50(bench: &Bencher, preset: &str, scheme: &str, bpe: f64, threads: usize) -> splitfc::util::Result<f64> {
    let mut cfg = TrainConfig::for_preset(preset);
    cfg.scheme = parse_scheme(scheme, 16.0)?;
    cfg.up_bits_per_entry = bpe;
    cfg.down_bits_per_entry = 32.0;
    cfg.threads = threads;
    // set the pool explicitly: cfg.threads = 0 means "leave the pool alone",
    // but this bench really does want auto in that case
    par::set_threads(threads);
    let mut tr = Trainer::new(cfg)?;
    let tn = par::threads();
    let mut t = 0usize;
    let st = bench.run(&format!("step/{preset}/{scheme}/threads={tn}"), || {
        t += 1;
        tr.step(t, t % 2).expect("step")
    });
    println!("{}", st.report());
    Ok(st.p50_s)
}

/// Blocked+threaded kernels vs the pre-blocking scalar references on the
/// mnist device-forward shape — the pure-kernel leg of the speedup story.
fn matmul_micro(bench: &Bencher, threads_req: usize) -> Vec<(&'static str, f64, f64)> {
    let (n, m, p) = (32usize, 784usize, 1152usize);
    let mut rng = Rng::new(9);
    // ~half zeros, like post-ReLU activations (the regime the old kernel's
    // zero-skip branch targeted)
    let a = Matrix::from_fn(n, m, |_, _| {
        let v = rng.normal_f32(0.0, 1.0);
        if v < 0.0 {
            0.0
        } else {
            v
        }
    });
    let b = Matrix::from_fn(m, p, |_, _| rng.normal_f32(0.0, 0.1));
    let bt = Matrix::from_fn(p, m, |r, c| b.at(c, r));
    par::set_threads(threads_req);
    let mut out = Vec::new();
    let ref_s = bench.run("matmul_ref/32x784x1152", || a.matmul_ref(&b)).p50_s;
    let new_s = bench.run("matmul/32x784x1152", || a.matmul(&b)).p50_s;
    out.push(("matmul", ref_s, new_s));
    let ref_s = bench.run("matmul_nt_ref/32x784x1152", || a.matmul_nt_ref(&bt)).p50_s;
    let new_s = bench.run("matmul_nt/32x784x1152", || a.matmul_nt(&bt)).p50_s;
    out.push(("matmul_nt", ref_s, new_s));
    for (name, r, nw) in &out {
        println!("{name}: scalar ref p50 {:.3}ms vs blocked+threaded {:.3}ms ({:.2}x)",
            r * 1e3, nw * 1e3, r / nw);
    }
    out
}

fn main() -> splitfc::util::Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let threads_req = par::thread_request(args.get_usize("threads", 0));
    let bench = if quick {
        Bencher::quick()
    } else {
        Bencher { min_time_s: 2.0, warmup_s: 0.3, max_iters: 200 }
    };

    let presets: &[&str] = if quick { &["tiny"] } else { &["tiny", "mnist"] };
    let schemes: &[(&str, f64)] = if quick {
        &[("splitfc", 0.2)]
    } else {
        &[("vanilla", 32.0), ("splitfc", 0.2), ("tops", 0.2)]
    };

    let mut rows: Vec<Json> = Vec::new();
    for preset in presets {
        for (scheme, bpe) in schemes {
            let serial = step_p50(&bench, preset, scheme, *bpe, 1)?;
            let threaded = step_p50(&bench, preset, scheme, *bpe, threads_req)?;
            let tn = par::threads();
            rows.push(Json::obj(vec![
                ("preset", Json::str(*preset)),
                ("scheme", Json::str(*scheme)),
                ("threads", Json::num(tn as f64)),
                ("serial_ns_per_op", Json::num(serial * 1e9)),
                ("threaded_ns_per_op", Json::num(threaded * 1e9)),
                ("speedup", Json::num(serial / threaded)),
            ]));
        }
    }

    let micro = matmul_micro(&bench, threads_req);
    let micro_json: Vec<Json> = micro
        .iter()
        .map(|(name, r, nw)| {
            Json::obj(vec![
                ("kernel", Json::str(*name)),
                ("scalar_ref_ns_per_op", Json::num(r * 1e9)),
                ("blocked_ns_per_op", Json::num(nw * 1e9)),
                ("speedup", Json::num(r / nw)),
            ])
        })
        .collect();

    let j = Json::obj(vec![
        ("bench", Json::str("e2e_step")),
        ("threads", Json::num(par::threads() as f64)),
        ("steps", Json::Arr(rows)),
        ("matmul_micro_32x784x1152", Json::Arr(micro_json)),
    ]);
    std::fs::write("BENCH_e2e.json", j.to_string_pretty()).expect("write BENCH_e2e.json");
    println!("[saved BENCH_e2e.json]");
    Ok(())
}
