//! End-to-end step latency: one full (t, k) protocol step (device_fwd ->
//! stats -> FWDP/FWQ -> server_fwd_bwd -> downlink -> device_bwd -> ADAM)
//! through the PJRT runtime, per preset and scheme. Requires artifacts.

use splitfc::bench::Bencher;
use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;

fn main() -> splitfc::util::Result<()> {
    let bench = Bencher { min_time_s: 2.0, warmup_s: 0.3, max_iters: 200 };
    for preset in ["tiny", "mnist"] {
        for (scheme, bpe) in [("vanilla", 32.0), ("splitfc", 0.2), ("tops", 0.2)] {
            let mut cfg = TrainConfig::for_preset(preset);
            cfg.scheme = parse_scheme(scheme, 16.0);
            cfg.up_bits_per_entry = bpe;
            cfg.down_bits_per_entry = 32.0;
            let mut tr = Trainer::new(cfg)?;
            let mut t = 0usize;
            let st = bench.run(&format!("step/{preset}/{scheme}"), || {
                t += 1;
                tr.step(t, t % 2).expect("step")
            });
            println!("{}", st.report());
        }
    }
    Ok(())
}
