//! Hot-path microbenchmarks: the FWDP/FWQ codec and every baseline on an
//! MNIST-shaped intermediate matrix (B=64, Dbar=1152). This is the L3
//! perf gate: codec throughput must far exceed the simulated link rate so
//! the coordinator is never the bottleneck (DESIGN.md §Perf).

use splitfc::bench::{Bencher, BenchStats};
use splitfc::compression::{
    encode_downlink, encode_uplink, CodecParams, DropKind, FwqMode, ScalarKind, Scheme,
};
use splitfc::tensor::{column_stats, normalized_sigma, Matrix};
use splitfc::util::Rng;

fn main() {
    let (b, d) = (64usize, 1152usize);
    let mut rng = Rng::new(3);
    let f = Matrix::from_fn(b, d, |_, c| {
        let scale = [4.0, 1.0, 0.2, 0.02, 0.0][c % 5];
        scale * rng.normal_f32(0.0, 1.0) + (c % 13) as f32 * 0.1
    });
    let sigma = normalized_sigma(&column_stats(&f), 36);
    let entries = (b * d) as f64;

    let bench = Bencher::default();
    let mut all: Vec<BenchStats> = Vec::new();
    let schemes: Vec<(&str, Scheme, f64)> = vec![
        ("uplink/vanilla-dump", Scheme::Vanilla, 32.0),
        ("uplink/splitfc-R16@0.2", Scheme::splitfc(16.0), 0.2),
        ("uplink/splitfc-R8@0.4", Scheme::splitfc(8.0), 0.4),
        (
            "uplink/splitfc-ad-only",
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 16.0, quant: FwqMode::NoQuant },
            32.0,
        ),
        (
            "uplink/ad+eq@0.2",
            Scheme::SplitFc {
                drop: Some(DropKind::Adaptive),
                r: 16.0,
                quant: FwqMode::Scalar(ScalarKind::Eq),
            },
            0.2,
        ),
        ("uplink/tops@0.2", Scheme::TopS { theta: 0.0, quant: None }, 0.2),
        ("uplink/randtops@0.2", Scheme::TopS { theta: 0.2, quant: None }, 0.2),
        ("uplink/fedlite@0.2", Scheme::FedLite { num_subvectors: 16 }, 0.2),
    ];
    for (name, scheme, bpe) in &schemes {
        let params = CodecParams::new(b, d, *bpe);
        let mut rng = Rng::new(11);
        let mut st = bench.run(name, || {
            encode_uplink(scheme, &f, &sigma, &params, &mut rng).frame.payload_bits
        });
        st.throughput = Some((entries / st.p50_s / 1e6, "Mentries/s"));
        println!("{}", st.report());
        all.push(st);
    }

    // downlink with column mask (SplitFC path)
    let params = CodecParams::new(b, d, 0.2);
    let mut rng2 = Rng::new(5);
    let enc = encode_uplink(&Scheme::splitfc(16.0), &f, &sigma, &params, &mut rng2);
    let g = Matrix::from_fn(b, d, |r, c| ((r * 31 + c) % 11) as f32 * 0.01 - 0.05);
    let mut st = bench.run("downlink/splitfc-R16@0.2", || {
        encode_downlink(&Scheme::splitfc(16.0), &g, &enc.mask, &params).frame.payload_bits
    });
    st.throughput = Some((entries / st.p50_s / 1e6, "Mentries/s"));
    println!("{}", st.report());

    // coordinator-not-the-bottleneck check: the codec must cost far less
    // wall time than the transfer time it *saves* (uncompressed-vs-
    // compressed at a 10 Mbps device uplink, the paper's link).
    let splitfc = &all[1];
    let uncompressed_s = (32.0 * entries) / 10e6;
    let compressed_s = (0.2 * entries) / 10e6;
    let saved = uncompressed_s - compressed_s;
    println!(
        "\nsplitfc encode p50 {:.2}ms vs transfer-time saved {:.0}ms/step on a 10 Mbps link \
         => codec overhead is {:.2}% of the saving",
        splitfc.p50_s * 1e3,
        saved * 1e3,
        100.0 * splitfc.p50_s / saved
    );
}
