//! Hot-path microbenchmarks: the FWDP/FWQ codec and every baseline on an
//! MNIST-shaped intermediate matrix (B=64, Dbar=1152), plus the paper-scale
//! FWQ encode (B=64, D̄=8192 — the Sec. VII regime) measured serial vs
//! threaded. This is the L3 perf gate: codec throughput must far exceed the
//! simulated link rate so the coordinator is never the bottleneck
//! (DESIGN.md §Perf).
//!
//! The paper-scale section writes `BENCH_fwq.json` (ns/op for `threads = 1`
//! and the configured pool, speedup, M*, bits) — the repo's perf-trajectory
//! record. Thread count comes from `THREADS=<n>` or `-- --threads <n>`
//! (0/unset = one worker per core); `-- --quick` shortens the run for CI
//! smoke.

use splitfc::bench::{Bencher, BenchStats};
use splitfc::compression::{
    encode_downlink, encode_uplink, fwq_encode, registered_names, CodecParams, CodecSpec,
    DropKind, FwqConfig, FwqMode, ScalarKind, Scheme, SigmaStats,
};
use splitfc::tensor::{column_stats, normalized_sigma, Matrix};
use splitfc::testkit::hetero_matrix;
use splitfc::util::{par, Args, Json, Rng};

fn main() {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let threads_req = par::thread_request(args.get_usize("threads", 0));
    par::set_threads(threads_req);
    let bench = if quick { Bencher::quick() } else { Bencher::default() };

    let (b, d) = (64usize, 1152usize);
    let f = hetero_matrix(b, d, 3);
    let sigma = normalized_sigma(&column_stats(&f), 36);
    let entries = (b * d) as f64;

    let mut all: Vec<BenchStats> = Vec::new();
    let schemes: Vec<(&str, Scheme, f64)> = if quick {
        vec![
            ("uplink/vanilla-dump", Scheme::Vanilla, 32.0),
            ("uplink/splitfc-R16@0.2", Scheme::splitfc(16.0), 0.2),
        ]
    } else {
        vec![
            ("uplink/vanilla-dump", Scheme::Vanilla, 32.0),
            ("uplink/splitfc-R16@0.2", Scheme::splitfc(16.0), 0.2),
            ("uplink/splitfc-R8@0.4", Scheme::splitfc(8.0), 0.4),
            (
                "uplink/splitfc-ad-only",
                Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 16.0, quant: FwqMode::NoQuant },
                32.0,
            ),
            (
                "uplink/ad+eq@0.2",
                Scheme::SplitFc {
                    drop: Some(DropKind::Adaptive),
                    r: 16.0,
                    quant: FwqMode::Scalar(ScalarKind::Eq),
                },
                0.2,
            ),
            ("uplink/tops@0.2", Scheme::TopS { theta: 0.0, quant: None }, 0.2),
            ("uplink/randtops@0.2", Scheme::TopS { theta: 0.2, quant: None }, 0.2),
            ("uplink/fedlite@0.2", Scheme::FedLite { num_subvectors: 16 }, 0.2),
        ]
    };
    for (name, scheme, bpe) in &schemes {
        let params = CodecParams::new(b, d, *bpe);
        let mut rng = Rng::new(11);
        let mut st = bench.run(name, || {
            encode_uplink(scheme, &f, &sigma, &params, &mut rng).frame.payload_bits
        });
        st.throughput = Some((entries / st.p50_s / 1e6, "Mentries/s"));
        println!("{}", st.report());
        all.push(st);
    }

    // downlink with column mask (SplitFC path)
    let params = CodecParams::new(b, d, 0.2);
    let mut rng2 = Rng::new(5);
    let enc = encode_uplink(&Scheme::splitfc(16.0), &f, &sigma, &params, &mut rng2);
    let g = Matrix::from_fn(b, d, |r, c| ((r * 31 + c) % 11) as f32 * 0.01 - 0.05);
    let mut st = bench.run("downlink/splitfc-R16@0.2", || {
        encode_downlink(&Scheme::splitfc(16.0), &g, &enc.mask, &params).frame.payload_bits
    });
    st.throughput = Some((entries / st.p50_s / 1e6, "Mentries/s"));
    println!("{}", st.report());

    // coordinator-not-the-bottleneck check: the codec must cost far less
    // wall time than the transfer time it *saves* (uncompressed-vs-
    // compressed at a 10 Mbps device uplink, the paper's link).
    let splitfc = &all[1];
    let uncompressed_s = (32.0 * entries) / 10e6;
    let compressed_s = (0.2 * entries) / 10e6;
    let saved = uncompressed_s - compressed_s;
    println!(
        "\nsplitfc encode p50 {:.2}ms vs transfer-time saved {:.0}ms/step on a 10 Mbps link \
         => codec overhead is {:.2}% of the saving",
        splitfc.p50_s * 1e3,
        saved * 1e3,
        100.0 * splitfc.p50_s / saved
    );

    let codec_stats = registry_sweep(&bench, quick, b, d, &f, &sigma);
    fwq_paper_scale(&bench, threads_req, codec_stats);
}

/// Sweep every registered codec by name through the trait-dispatch path
/// (one session reused across iterations, like the worker does) and record
/// per-codec encode ns/op. The `codec/splitfc` row is directly comparable
/// to `uplink/splitfc-R16@0.2` above (the enum-shim path), so a dispatch
/// regression shows up as a gap between the two.
fn registry_sweep(
    bench: &Bencher,
    quick: bool,
    b: usize,
    d: usize,
    f: &Matrix,
    sigma: &[f32],
) -> Vec<(String, f64)> {
    let stats = SigmaStats::new(sigma.to_vec());
    let names = registered_names();
    let names: Vec<String> = if quick {
        names.into_iter().filter(|n| ["vanilla", "splitfc", "tops"].contains(&n.as_str())).collect()
    } else {
        names
    };
    let mut out = Vec::new();
    for name in &names {
        let spec = match CodecSpec::parse_with_r(name, 16.0) {
            Ok(s) => s,
            Err(e) => panic!("{name}: {e}"),
        };
        let mut codec = spec.build().unwrap_or_else(|e| panic!("{name}: {e}"));
        let bpe = if name == "vanilla" { 32.0 } else { 0.2 };
        let params = CodecParams::new(b, d, bpe);
        let mut rng = Rng::new(11);
        let mut st = bench.run(&format!("codec/{name}"), || {
            codec
                .encode_uplink(f, Some(&stats), &params, &mut rng)
                .expect("encode")
                .frame
                .payload_bits
        });
        st.throughput = Some(((b * d) as f64 / st.p50_s / 1e6, "Mentries/s"));
        println!("{}", st.report());
        out.push((name.clone(), st.p50_s * 1e9));
    }
    out
}

/// FWQ at the paper's D̄ = 8192 scale: serial baseline vs the thread pool,
/// with a byte-identity cross-check, recorded to BENCH_fwq.json together
/// with the per-codec registry sweep (ns/op per registered codec).
fn fwq_paper_scale(bench: &Bencher, threads_req: usize, codec_stats: Vec<(String, f64)>) {
    let (b, d) = (64usize, 8192usize);
    let a = hetero_matrix(b, d, 42);
    let cfg = FwqConfig::paper_default(b, 0.2 * (b * d) as f64);

    par::set_threads(1);
    let st1 = bench.run("fwq/B=64,D=8192,0.2bpe/threads=1", || fwq_encode(&a, &cfg).1);
    println!("{}", st1.report());
    let (bytes_serial, _, _) = fwq_encode(&a, &cfg);

    par::set_threads(threads_req);
    let tn = par::threads();
    let stn = bench.run(&format!("fwq/B=64,D=8192,0.2bpe/threads={tn}"), || {
        fwq_encode(&a, &cfg).1
    });
    println!("{}", stn.report());
    let (bytes_threaded, bits, info) = fwq_encode(&a, &cfg);
    let identical = bytes_serial == bytes_threaded;

    let speedup = st1.p50_s / stn.p50_s;
    println!(
        "fwq paper scale: {:.2}x speedup with {tn} threads, M*={}, {} bits, \
         bitstream byte-identical to serial: {identical}",
        speedup, info.m_star, bits
    );

    let j = Json::obj(vec![
        ("bench", Json::str("fwq_encode")),
        ("batch", Json::num(b as f64)),
        ("dbar", Json::num(d as f64)),
        ("bits_per_entry_budget", Json::num(0.2)),
        ("threads", Json::num(tn as f64)),
        ("serial_ns_per_op", Json::num(st1.p50_s * 1e9)),
        ("threaded_ns_per_op", Json::num(stn.p50_s * 1e9)),
        ("speedup", Json::num(speedup)),
        ("m_star", Json::num(info.m_star as f64)),
        ("bits", Json::num(bits as f64)),
        ("byte_identical_vs_serial", Json::Bool(identical)),
        (
            "codec_encode_ns_per_op",
            Json::Obj(
                codec_stats.into_iter().map(|(n, ns)| (n, Json::num(ns))).collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_fwq.json", j.to_string_pretty()).expect("write BENCH_fwq.json");
    println!("[saved BENCH_fwq.json]");
}
