//! Coordinator throughput: protocol steps/s of the ParameterServer /
//! DeviceWorker scheduler vs number of devices K ∈ {1, 4, 8} at staleness
//! S ∈ {0, 2}, on the mnist-scenario preset (the heaviest native step).
//!
//! S = 0 resolves to the sequential Algorithm-1 baseline; S = 2 runs one
//! worker thread per device with a 2-round staleness window, so device-side
//! compute and codec work overlap across clients while the PS critical
//! section stays serialized. The inner compute pool is pinned to **one**
//! thread by default — the coordinator's worker threads are the parallelism
//! under test (override with `-- --threads N` to measure combined scaling).
//!
//! Writes `BENCH_coordinator.json`; `-- --quick` shortens the run for CI.

use splitfc::config::parse_scheme;
use splitfc::config::TrainConfig;
use splitfc::coordinator::Trainer;
use splitfc::util::{par, Args, Json, Result};

fn run_one(
    devices: usize,
    staleness: usize,
    steps_target: usize,
    inner_threads: usize,
) -> Result<Json> {
    let mut cfg = TrainConfig::for_preset("mnist");
    cfg.devices = devices;
    cfg.rounds = (steps_target / devices).max(2);
    cfg.n_train = 512;
    cfg.n_test = 128;
    cfg.eval_every = 0;
    cfg.scheme = parse_scheme("splitfc", 16.0).expect("scheme");
    cfg.up_bits_per_entry = 0.2;
    cfg.down_bits_per_entry = 32.0;
    cfg.staleness = staleness;
    // explicit inner-pool size: every config measures the same per-step
    // compute, so the only variable is coordinator-level concurrency
    cfg.threads = inner_threads;
    let workers = cfg.resolved_concurrency();
    let mut tr = Trainer::new(cfg)?;
    let s = tr.run()?;
    let steps_per_s = s.steps as f64 / s.wall_s;
    println!(
        "K={devices} S={staleness} workers={workers}: {} steps in {:.3}s -> {:.2} steps/s",
        s.steps, s.wall_s, steps_per_s
    );
    Ok(Json::obj(vec![
        ("preset", Json::str("mnist")),
        ("devices", Json::num(devices as f64)),
        ("staleness", Json::num(staleness as f64)),
        ("workers", Json::num(workers as f64)),
        ("steps", Json::num(s.steps as f64)),
        ("wall_s", Json::num(s.wall_s)),
        ("steps_per_s", Json::num(steps_per_s)),
    ]))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let inner_threads = par::thread_request(args.get_usize("threads", 1)).max(1);
    par::set_threads(inner_threads);
    let steps_target = if quick { 16 } else { 48 };

    let mut rows = Vec::new();
    let mut baseline_by_k = Vec::new();
    for &devices in &[1usize, 4, 8] {
        for &staleness in &[0usize, 2] {
            let row = run_one(devices, staleness, steps_target, inner_threads)?;
            let sps = row.req("steps_per_s").as_f64().unwrap();
            if staleness == 0 {
                baseline_by_k.push((devices, sps));
            } else if let Some(&(_, base)) =
                baseline_by_k.iter().find(|&&(k, _)| k == devices)
            {
                println!(
                    "  K={devices}: staleness-2 speedup over sequential {:.2}x",
                    sps / base
                );
            }
            rows.push(row);
        }
    }

    let j = Json::obj(vec![
        ("bench", Json::str("coordinator")),
        ("inner_threads", Json::num(par::threads() as f64)),
        ("steps_target", Json::num(steps_target as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_coordinator.json", j.to_string_pretty())
        .expect("write BENCH_coordinator.json");
    println!("[saved BENCH_coordinator.json]");
    Ok(())
}
