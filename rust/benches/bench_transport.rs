//! Transport throughput + tail latency: protocol steps/s and per-step
//! p50/p99 over the in-process channel backend vs real TCP loopback, for
//! fleet sizes K ∈ {1, 4, 16} on the tiny preset at staleness 0 (so both
//! backends drive the byte-identical sequential schedule and the *only*
//! variable is the transport).
//!
//! Also probes the connection lifecycle: a handshake with a mismatched
//! codec must be rejected, and a mid-training socket cut (request
//! delivered, reply lost) must recover through reconnect + courier replay
//! without losing a step — the bench **fails** (non-zero exit) if either
//! probe misbehaves, so CI catches lifecycle regressions alongside perf.
//!
//! Writes `BENCH_transport.json`; `-- --quick` shortens the run for CI.

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::transport::{Connection, Msg, TcpConn, TransportKind, WireLimits};
use splitfc::util::{par, Args, Json, Result};

fn cfg_for(devices: usize, steps_target: usize, transport: TransportKind) -> TrainConfig {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = devices;
    cfg.rounds = (steps_target / devices).max(2);
    cfg.n_train = 256;
    cfg.n_test = 32;
    cfg.eval_every = 0;
    cfg.scheme = parse_scheme("splitfc", 8.0).expect("scheme");
    cfg.up_bits_per_entry = 1.0;
    cfg.down_bits_per_entry = 4.0;
    cfg.transport = transport;
    cfg
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn run_one(devices: usize, steps_target: usize, transport: TransportKind) -> Result<Json> {
    let path = std::env::temp_dir().join(format!(
        "splitfc_bench_tx_{}_{devices}_{}.jsonl",
        transport.name(),
        std::process::id()
    ));
    let mut cfg = cfg_for(devices, steps_target, transport);
    cfg.metrics_path = path.to_str().unwrap().to_string();
    let mut tr = Trainer::new(cfg)?;
    let s = tr.run()?;
    drop(tr);

    // per-step latency distribution from the metrics stream
    let text = std::fs::read_to_string(&path).map_err(|e| splitfc::err!("metrics: {e}"))?;
    let mut step_s: Vec<f64> = text
        .lines()
        .filter_map(|l| Json::parse(l).ok())
        .filter(|j| j.get("t").is_some())
        .filter_map(|j| j.req("step_s").as_f64())
        .collect();
    std::fs::remove_file(&path).ok();
    splitfc::ensure!(step_s.len() == s.steps, "metrics stream incomplete");
    step_s.sort_by(|a, b| a.total_cmp(b));
    let (p50, p99) = (percentile(&step_s, 0.50), percentile(&step_s, 0.99));
    let steps_per_s = s.steps as f64 / s.wall_s;
    println!(
        "{:<6} K={devices:<2}: {} steps in {:.3}s -> {:>8.2} steps/s, p50 {:.4}s p99 {:.4}s",
        transport.name(),
        s.steps,
        s.wall_s,
        steps_per_s,
        p50,
        p99
    );
    Ok(Json::obj(vec![
        ("transport", Json::str(transport.name())),
        ("devices", Json::num(devices as f64)),
        ("steps", Json::num(s.steps as f64)),
        ("wall_s", Json::num(s.wall_s)),
        ("steps_per_s", Json::num(steps_per_s)),
        ("p50_step_s", Json::num(p50)),
        ("p99_step_s", Json::num(p99)),
    ]))
}

/// Lifecycle probe 1: a Hello with a bogus codec identity must be rejected
/// by the PS handshake (and an out-of-range device index likewise).
fn probe_handshake() -> Result<()> {
    let mut cfg = cfg_for(2, 4, TransportKind::Tcp);
    cfg.rounds = 1;
    let tr = Trainer::new(cfg)?;
    let addr = tr.listen_addr().expect("tcp trainer listens").to_string();
    let mut conn = TcpConn::connect(&addr, WireLimits::new(1 << 20))?;
    conn.send(Msg::Hello { device: 0, codec_id: 0xBAD_C0DE, codec_version: 0xFFFF })?;
    match conn.recv()? {
        Msg::HelloAck { err: Some(_), .. } => {}
        other => splitfc::bail!("codec-mismatch hello was not rejected: {other:?}"),
    }
    let mut conn = TcpConn::connect(&addr, WireLimits::new(1 << 20))?;
    conn.send(Msg::Hello { device: 1000, codec_id: 0, codec_version: 0 })?;
    match conn.recv()? {
        Msg::HelloAck { err: Some(_), .. } => {}
        other => splitfc::bail!("out-of-range hello was not rejected: {other:?}"),
    }
    println!("handshake probe ok (mismatches rejected)");
    Ok(())
}

/// Lifecycle probe 2: cut device 0's socket right after a mid-run uplink
/// is delivered — the run must recover via reconnect + replay and finish
/// every scheduled step.
fn probe_reconnect() -> Result<()> {
    let mut cfg = cfg_for(2, 8, TransportKind::Tcp);
    cfg.scenario.push_cut(0, 6); // Hello + step 1 (3 sends) + round-2 Uplink
    let rounds = cfg.rounds;
    let mut tr = Trainer::new(cfg)?;
    let s = tr.run()?;
    splitfc::ensure!(
        s.steps == rounds * 2,
        "reconnect probe lost steps: {} of {}",
        s.steps,
        rounds * 2
    );
    println!("reconnect probe ok ({} steps across a link cut)", s.steps);
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let inner_threads = par::thread_request(args.get_usize("threads", 1)).max(1);
    par::set_threads(inner_threads);
    let steps_target = if quick { 16 } else { 64 };

    probe_handshake()?;
    probe_reconnect()?;

    let mut rows = Vec::new();
    for &devices in &[1usize, 4, 16] {
        let mut pair = Vec::new();
        for transport in [TransportKind::InProc, TransportKind::Tcp] {
            let row = run_one(devices, steps_target, transport)?;
            pair.push(row.req("steps_per_s").as_f64().unwrap());
            rows.push(row);
        }
        if let [inproc, tcp] = pair[..] {
            println!("  K={devices}: tcp/inproc throughput ratio {:.2}", tcp / inproc);
        }
    }

    let j = Json::obj(vec![
        ("bench", Json::str("transport")),
        ("preset", Json::str("tiny")),
        ("inner_threads", Json::num(par::threads() as f64)),
        ("steps_target", Json::num(steps_target as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_transport.json", j.to_string_pretty())
        .expect("write BENCH_transport.json");
    println!("[saved BENCH_transport.json]");
    Ok(())
}
