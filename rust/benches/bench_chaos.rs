//! Failure-scenario engine bench: training throughput, step-latency tails,
//! and accuracy-vs-round under calm, straggler and churn scenarios on the
//! tiny preset over TCP loopback — plus a FWQ-vs-fixed-quantization
//! comparison under a slow link with a straggler, an MTTR sweep (a mid-run
//! `pscrash` with live devices, reporting restarts / time-to-recover /
//! replay absorbed), and determinism probes (the same `--scenario` spec
//! twice — churn AND pscrash — must reproduce the deterministic step
//! fields exactly; the bench **fails** non-zero if it does not).
//!
//! Writes `BENCH_chaos.json`; `-- --quick` shortens the run for CI.

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::scenario::ScenarioSpec;
use splitfc::transport::TransportKind;
use splitfc::util::{par, Args, Json, Result};

fn cfg_for(rounds: usize, scenario: &str) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = rounds;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.eval_every = 0;
    cfg.seed = 11;
    cfg.scheme = parse_scheme("splitfc", 8.0)?;
    cfg.up_bits_per_entry = 1.0;
    cfg.down_bits_per_entry = 4.0;
    cfg.transport = TransportKind::Tcp;
    cfg.scenario = ScenarioSpec::parse(scenario)?;
    // a transient cut must never be declared a departure mid-bench
    cfg.retry_deadline_s = 10.0;
    cfg.liveness_timeout_s = 0.0;
    Ok(cfg)
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Deterministic per-step fields of one metrics stream, in order (the
/// wall-clock fields `step_s`/`exec_s` are excluded on purpose: stragglers
/// stretch them without touching the trajectory).
fn step_fields(path: &std::path::Path) -> Result<Vec<String>> {
    const KEYS: [&str; 9] = [
        "t", "k", "g", "loss", "train_acc", "up_bits", "down_bits", "up_nominal",
        "down_nominal",
    ];
    let text =
        std::fs::read_to_string(path).map_err(|e| splitfc::err!("metrics {path:?}: {e}"))?;
    let mut rows = Vec::new();
    for line in text.lines() {
        let Ok(j) = Json::parse(line) else { continue };
        if j.get("g").is_none() {
            continue;
        }
        let mut fields = Vec::with_capacity(KEYS.len());
        for k in KEYS {
            let v = j
                .get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| splitfc::err!("step record missing {k:?}"))?;
            fields.push(format!("{k}={v:?}"));
        }
        rows.push(fields.join(" "));
    }
    Ok(rows)
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("splitfc_bench_chaos_{tag}_{}.jsonl", std::process::id()))
}

/// One scenario sweep row: run the tiny fleet under `scenario` and report
/// throughput, latency tails and the degradation counters.
fn run_scenario(label: &str, scenario: &str, rounds: usize) -> Result<Json> {
    let path = tmp_path(label);
    let mut cfg = cfg_for(rounds, scenario)?;
    cfg.metrics_path = path.to_str().unwrap().to_string();
    let scheduled = cfg.rounds * cfg.devices;
    let mut tr = Trainer::new(cfg)?;
    let s = tr.run()?;
    let rep = tr.link_report();
    drop(tr);

    let text = std::fs::read_to_string(&path).map_err(|e| splitfc::err!("metrics: {e}"))?;
    let mut step_s: Vec<f64> = text
        .lines()
        .filter_map(|l| Json::parse(l).ok())
        .filter(|j| j.get("g").is_some())
        .filter_map(|j| j.get("step_s").and_then(|v| v.as_f64()))
        .collect();
    std::fs::remove_file(&path).ok();
    step_s.sort_by(|a, b| a.total_cmp(b));
    let (p50, p99) = (percentile(&step_s, 0.50), percentile(&step_s, 0.99));
    let steps_per_s = s.steps as f64 / s.wall_s;
    println!(
        "{label:<10}: {}/{} steps in {:.3}s -> {:>7.2} steps/s, p50 {:.4}s p99 {:.4}s, \
         acc {:.4}, retries {}, departed {}",
        s.steps, scheduled, s.wall_s, steps_per_s, p50, p99, s.final_acc,
        rep.retry_attempts, s.departed
    );
    Ok(Json::obj(vec![
        ("scenario", Json::str(label)),
        ("spec", Json::str(scenario)),
        ("steps", Json::num(s.steps as f64)),
        ("steps_scheduled", Json::num(scheduled as f64)),
        ("wall_s", Json::num(s.wall_s)),
        ("steps_per_s", Json::num(steps_per_s)),
        ("p50_step_s", Json::num(p50)),
        ("p99_step_s", Json::num(p99)),
        ("final_acc", Json::num(s.final_acc as f64)),
        ("mean_loss_last_round", Json::num(s.mean_loss_last_round as f64)),
        ("retry_attempts", Json::num(rep.retry_attempts as f64)),
        ("backoff_s", Json::num(rep.backoff_s)),
        ("departed", Json::num(s.departed as f64)),
    ]))
}

/// FWQ (adaptive levels) vs a fixed 8-level quantizer at the same bit
/// budget, run under a slow link with one straggler: the adaptive codec's
/// accuracy-vs-round curve is the paper's argument, and the modeled link
/// time shows what the budget costs on a 100 kbps wire.
fn run_quantizer_cmp(rounds: usize) -> Result<Vec<Json>> {
    let mut rows = Vec::new();
    for (label, scheme) in [("fwq", "splitfc[ad,R=8,fwq]"), ("fixedQ8", "splitfc[ad,R=8,fixedQ8]")] {
        let mut cfg = cfg_for(rounds, "seed=7,straggler[dev=1,slow=4x]")?;
        cfg.scheme = parse_scheme(scheme, 8.0)?;
        cfg.link_capacity_bps = 100e3;
        cfg.eval_every = 2;
        let mut tr = Trainer::new(cfg)?;
        let s = tr.run()?;
        let rep = tr.link_report();
        drop(tr);
        println!(
            "quantizer {label:<8}: acc {:.4}, {} up bits, modeled link {:.2}s, evals {:?}",
            s.final_acc, s.total_up_bits, rep.elapsed_s, s.eval_history
        );
        rows.push(Json::obj(vec![
            ("quantizer", Json::str(label)),
            ("scheme", Json::str(scheme)),
            ("final_acc", Json::num(s.final_acc as f64)),
            ("total_up_bits", Json::num(s.total_up_bits as f64)),
            ("link_s", Json::num(rep.elapsed_s)),
            (
                "eval_history",
                Json::Arr(
                    s.eval_history
                        .iter()
                        .map(|&(t, a)| Json::Arr(vec![Json::num(t as f64), Json::num(a as f64)]))
                        .collect(),
                ),
            ),
        ]));
    }
    Ok(rows)
}

/// MTTR sweep: crash + restart the PS in-process at the mid-run barrier,
/// live TCP devices riding it out through their reconnect loops, and
/// report the run's recovery telemetry.
fn run_recovery(rounds: usize) -> Result<Json> {
    let crash_at = (rounds / 2).max(1);
    let spec = format!("pscrash[round={crash_at}]");
    let path = tmp_path("recovery");
    let dir =
        std::env::temp_dir().join(format!("splitfc_bench_chaos_ckpt_{}", std::process::id()));
    let mut cfg = cfg_for(rounds, &spec)?;
    cfg.metrics_path = path.to_str().unwrap().to_string();
    cfg.checkpoint_every = crash_at;
    cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
    let scheduled = cfg.rounds * cfg.devices;
    let mut tr = Trainer::new(cfg)?;
    let s = tr.run()?;
    let rep = tr.link_report();
    drop(tr);
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "recovery  : {}/{} steps in {:.3}s, {} restart(s), MTTR {:.4}s, \
         {} step(s) replayed, retries {}",
        s.steps, scheduled, s.wall_s, s.ps_restarts, s.recover_s, s.steps_replayed,
        rep.retry_attempts
    );
    Ok(Json::obj(vec![
        ("scenario", Json::str("recovery")),
        ("spec", Json::str(spec)),
        ("steps", Json::num(s.steps as f64)),
        ("steps_scheduled", Json::num(scheduled as f64)),
        ("wall_s", Json::num(s.wall_s)),
        ("final_acc", Json::num(s.final_acc as f64)),
        ("ps_restarts", Json::num(s.ps_restarts as f64)),
        ("recover_s", Json::num(s.recover_s)),
        ("steps_replayed", Json::num(s.steps_replayed as f64)),
        ("retry_attempts", Json::num(rep.retry_attempts as f64)),
    ]))
}

/// Determinism probe for server-side chaos: two runs of the same pscrash
/// spec must reproduce the stream exactly — the crash fires at the same
/// barrier and the restore path is bit-faithful.
fn probe_pscrash_determinism(rounds: usize) -> Result<()> {
    let crash_at = (rounds / 2).max(1);
    let spec = format!("pscrash[round={crash_at}]");
    let mut streams = Vec::new();
    for pass in 0..2 {
        let path = tmp_path(&format!("psdet{pass}"));
        let dir = std::env::temp_dir()
            .join(format!("splitfc_bench_chaos_psdet{pass}_{}", std::process::id()));
        let mut cfg = cfg_for(rounds, &spec)?;
        cfg.metrics_path = path.to_str().unwrap().to_string();
        cfg.checkpoint_every = crash_at;
        cfg.checkpoint_dir = dir.to_str().unwrap().to_string();
        let mut tr = Trainer::new(cfg)?;
        tr.run()?;
        drop(tr);
        streams.push(step_fields(&path)?);
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir_all(&dir).ok();
    }
    splitfc::ensure!(
        streams[0] == streams[1],
        "pscrash determinism probe: two runs of {spec:?} diverged"
    );
    println!(
        "pscrash determinism probe ok ({} steps identical across two runs of {spec:?})",
        streams[0].len()
    );
    Ok(())
}

/// Determinism probe: the same churn spec twice must yield identical
/// deterministic step fields (same seeds ⇒ same timeline ⇒ same stream).
fn probe_determinism(scenario: &str, rounds: usize) -> Result<()> {
    let mut streams = Vec::new();
    for pass in 0..2 {
        let path = tmp_path(&format!("det{pass}"));
        let mut cfg = cfg_for(rounds, scenario)?;
        cfg.metrics_path = path.to_str().unwrap().to_string();
        let mut tr = Trainer::new(cfg)?;
        tr.run()?;
        drop(tr);
        streams.push(step_fields(&path)?);
        std::fs::remove_file(&path).ok();
    }
    splitfc::ensure!(
        streams[0] == streams[1],
        "determinism probe: two runs of {scenario:?} diverged \
         ({} vs {} steps)",
        streams[0].len(),
        streams[1].len()
    );
    println!(
        "determinism probe ok ({} steps identical across two runs of {scenario:?})",
        streams[0].len()
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let inner_threads = par::thread_request(args.get_usize("threads", 1)).max(1);
    par::set_threads(inner_threads);
    let rounds = if quick { 4 } else { 10 };

    let churn = "seed=7,cut[dev=0,step=2],dropout[p=0.15,rejoin=2r]";
    probe_determinism(churn, rounds)?;
    probe_pscrash_determinism(rounds)?;

    let mut rows = Vec::new();
    rows.push(run_scenario("calm", "", rounds)?);
    rows.push(run_scenario("straggler", "seed=7,straggler[dev=1,slow=4x]", rounds)?);
    rows.push(run_scenario("churn", churn, rounds)?);
    rows.push(run_recovery(rounds)?);

    let quant = run_quantizer_cmp(rounds)?;

    let j = Json::obj(vec![
        ("bench", Json::str("chaos")),
        ("preset", Json::str("tiny")),
        ("devices", Json::num(4.0)),
        ("rounds", Json::num(rounds as f64)),
        ("inner_threads", Json::num(par::threads() as f64)),
        ("rows", Json::Arr(rows)),
        ("quantizer_cmp", Json::Arr(quant)),
    ]);
    std::fs::write("BENCH_chaos.json", j.to_string_pretty()).expect("write BENCH_chaos.json");
    println!("[saved BENCH_chaos.json]");
    Ok(())
}
