//! Regenerates the paper's Table I (accuracy vs uplink compression) at bench scale (shrunken rounds/devices; the
//! same rows/series as the paper — run `splitfc experiment table1` with
//! --rounds/--devices/--presets for fuller scales).

use splitfc::coordinator::experiments;
use splitfc::util::Args;

fn main() -> splitfc::util::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let mut args = Args::parse(&argv);
    for (k, v) in [("rounds", "4"), ("devices", "4"), ("n-train", "1024"), ("n-test", "256")] {
        args.options.entry(k.to_string()).or_insert_with(|| v.to_string());
    }
    let t0 = std::time::Instant::now();
    experiments::run("table1", &args)?;
    println!("\n[bench_table1 completed in {:.1}s]", t0.elapsed().as_secs_f64());
    Ok(())
}
