//! SIMD hot-path kernel microbenchmarks → BENCH_simd.json.
//!
//! Scalar-vs-AVX2 ns/op for the four dispatched kernel families on the
//! paper-scale `B=64, D̄=8192` regime (serial — the SIMD win must be
//! measured inside one thread, the thread pool multiplies it):
//!
//! 1. **matmul** — the MR-blocked kernel with the AVX2 micro-kernels vs the
//!    blocked scalar table vs the naive `matmul_ref` oracle;
//! 2. **column_stats** — per-row min/max/sum/sumsq accumulation;
//! 3. **FWQ symbol quantize** — `fwq_quant_col` over D̄ contiguous columns
//!    of B entries (the uplink symbol loop);
//! 4. **FWQ symbol dequantize** — `fwq_dequant_col`, the decode mirror.
//!
//! Acceptance gates (hard asserts, AVX2 hosts only): the SIMD matmul must
//! beat `matmul_ref` by ≥ 2x and the AVX2 `fwq_quant_col` must beat the
//! scalar table by ≥ 2x. Hosts without AVX2 skip the vector rows and the
//! gates, and say so in the JSON (`"skipped": true`).
//!
//! `-- --quick` shortens runs for CI smoke.

use splitfc::bench::Bencher;
use splitfc::tensor::column_stats;
use splitfc::testkit::hetero_matrix;
use splitfc::util::simd::{self, ColSrc, SimdMode};
use splitfc::util::{par, Args, Json};

const B: usize = 64;
const DBAR: usize = 8192;

fn main() {
    let args = Args::from_env();
    let quick = args.has_flag("quick");
    let bench = if quick { Bencher::quick() } else { Bencher::default() };
    par::set_threads(1);

    let avx2 = simd::avx2_available();
    println!(
        "SIMD kernel benches (B={B}, D̄={DBAR}, serial): AVX2 {}",
        if avx2 { "available" } else { "NOT available — vector rows skipped" }
    );

    // ---- 1. matmul: naive ref vs blocked scalar vs blocked AVX2 ----
    // 64x256 · 256x1024 keeps one op in the low-ms range while still deep
    // enough that the micro-kernel dominates
    let (mk, mp) = (256usize, 1024usize);
    let a = hetero_matrix(B, mk, 3);
    let bm = hetero_matrix(mk, mp, 4);
    let st_mm_ref = bench.run("matmul/naive-ref", || a.matmul_ref(&bm).data[0]);
    println!("{}", st_mm_ref.report());
    simd::force_mode(SimdMode::Off);
    let st_mm_off = bench.run("matmul/blocked/simd=off", || a.matmul(&bm).data[0]);
    println!("{}", st_mm_off.report());
    let st_mm_avx = avx2.then(|| {
        simd::force_mode(SimdMode::Avx2);
        let st = bench.run("matmul/blocked/simd=avx2", || a.matmul(&bm).data[0]);
        println!("{}", st.report());
        st
    });
    let mm_speedup_ref = st_mm_avx.as_ref().map(|st| st_mm_ref.p50_s / st.p50_s);
    let mm_speedup_scalar = st_mm_avx.as_ref().map(|st| st_mm_off.p50_s / st.p50_s);

    // ---- 2. column_stats ----
    let f = hetero_matrix(B, DBAR, 5);
    simd::force_mode(SimdMode::Off);
    let st_cs_off = bench.run("column_stats/simd=off", || column_stats(&f).min[0]);
    println!("{}", st_cs_off.report());
    let st_cs_avx = avx2.then(|| {
        simd::force_mode(SimdMode::Avx2);
        let st = bench.run("column_stats/simd=avx2", || column_stats(&f).min[0]);
        println!("{}", st.report());
        st
    });
    let cs_speedup = st_cs_avx.as_ref().map(|st| st_cs_off.p50_s / st.p50_s);

    // ---- 3./4. FWQ symbol kernels, head to head on the tables ----
    // D̄ contiguous columns of B entries: column c is src[c*B .. (c+1)*B]
    // (compute-isolated; the strided access cost is the same for both
    // tables and belongs to the caller's blocking, not the kernel)
    let src = f.data.clone();
    let (lo, span, q) = (-4.0f64, 8.0f64, 64u64);
    let syms: Vec<u64> = (0..B * DBAR).map(|i| (i as u64).wrapping_mul(2_654_435_761) % q).collect();
    let ks = simd::kernels_for(SimdMode::Off);

    let mut out = vec![0u64; B];
    let st_q_off = bench.run("fwq_quant_col/simd=off", || {
        let mut acc = 0u64;
        for c in 0..DBAR {
            let col = ColSrc { src: &src, offset: c * B, stride: 1, scale: None };
            (ks.fwq_quant_col)(col, B, lo, span, q, &mut out);
            acc ^= out[0];
        }
        acc
    });
    println!("{}", st_q_off.report());

    let mut dst = vec![0.0f32; B * DBAR];
    let st_d_off = bench.run("fwq_dequant_col/simd=off", || {
        for c in 0..DBAR {
            (ks.fwq_dequant_col)(&syms[c * B..(c + 1) * B], lo, span, q, &mut dst, c * B, 1);
        }
        dst[0]
    });
    println!("{}", st_d_off.report());

    let (st_q_avx, st_d_avx) = if avx2 {
        let ka = simd::kernels_for(SimdMode::Avx2);
        let st_q = bench.run("fwq_quant_col/simd=avx2", || {
            let mut acc = 0u64;
            for c in 0..DBAR {
                let col = ColSrc { src: &src, offset: c * B, stride: 1, scale: None };
                (ka.fwq_quant_col)(col, B, lo, span, q, &mut out);
                acc ^= out[0];
            }
            acc
        });
        println!("{}", st_q.report());
        let st_d = bench.run("fwq_dequant_col/simd=avx2", || {
            for c in 0..DBAR {
                (ka.fwq_dequant_col)(&syms[c * B..(c + 1) * B], lo, span, q, &mut dst, c * B, 1);
            }
            dst[0]
        });
        println!("{}", st_d.report());
        (Some(st_q), Some(st_d))
    } else {
        (None, None)
    };
    let q_speedup = st_q_avx.as_ref().map(|st| st_q_off.p50_s / st.p50_s);
    let d_speedup = st_d_avx.as_ref().map(|st| st_d_off.p50_s / st.p50_s);

    // leave the process in auto mode (benches may grow follow-on sections)
    simd::configure("auto").expect("auto");

    let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
    let j = Json::obj(vec![
        ("bench", Json::str("simd_kernels")),
        ("batch", Json::num(B as f64)),
        ("dbar", Json::num(DBAR as f64)),
        ("avx2_available", Json::Bool(avx2)),
        ("skipped", Json::Bool(!avx2)),
        (
            "matmul_ns_per_op",
            Json::obj(vec![
                ("naive_ref", Json::num(st_mm_ref.p50_s * 1e9)),
                ("blocked_scalar", Json::num(st_mm_off.p50_s * 1e9)),
                ("blocked_avx2", opt(st_mm_avx.as_ref().map(|st| st.p50_s * 1e9))),
                ("speedup_avx2_vs_ref", opt(mm_speedup_ref)),
                ("speedup_avx2_vs_scalar", opt(mm_speedup_scalar)),
            ]),
        ),
        (
            "column_stats_ns_per_op",
            Json::obj(vec![
                ("scalar", Json::num(st_cs_off.p50_s * 1e9)),
                ("avx2", opt(st_cs_avx.as_ref().map(|st| st.p50_s * 1e9))),
                ("speedup", opt(cs_speedup)),
            ]),
        ),
        (
            "fwq_quant_ns_per_matrix",
            Json::obj(vec![
                ("scalar", Json::num(st_q_off.p50_s * 1e9)),
                ("avx2", opt(st_q_avx.as_ref().map(|st| st.p50_s * 1e9))),
                ("speedup", opt(q_speedup)),
            ]),
        ),
        (
            "fwq_dequant_ns_per_matrix",
            Json::obj(vec![
                ("scalar", Json::num(st_d_off.p50_s * 1e9)),
                ("avx2", opt(st_d_avx.as_ref().map(|st| st.p50_s * 1e9))),
                ("speedup", opt(d_speedup)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_simd.json", j.to_string_pretty()).expect("write BENCH_simd.json");
    println!("[saved BENCH_simd.json]");

    // ---- gates (AVX2 hosts only) ----
    if avx2 {
        let mm = mm_speedup_ref.unwrap_or(f64::NAN);
        let fq = q_speedup.unwrap_or(f64::NAN);
        assert!(
            mm >= 2.0,
            "AVX2 matmul speedup vs naive ref {mm:.2}x below the 2x acceptance gate"
        );
        assert!(
            fq >= 2.0,
            "AVX2 fwq_quant_col speedup {fq:.2}x below the 2x acceptance gate"
        );
        println!("2x SIMD gates: OK (matmul {mm:.2}x vs ref, fwq quant {fq:.2}x vs scalar)");
    } else {
        println!("SIMD gates skipped: host lacks AVX2 (scalar table is the only path)");
    }
}
