//! Offline API stub for the `xla` crate (xla-rs / xla_extension 0.5.x).
//!
//! The offline registry cannot resolve the real crate, so this stub mirrors
//! exactly the API surface `splitfc::runtime::pjrt` uses. It makes
//! `cargo build --features pjrt` type-check without network or a local XLA
//! install; every method panics with a pointer to the real dependency if it
//! is actually called. To execute HLO artifacts for real, point the `xla`
//! path dependency in the workspace `Cargo.toml` at a checkout of xla-rs
//! (or add a `[patch]` entry) — the signatures below match.

const STUB_MSG: &str =
    "xla stub: the real xla-rs/PJRT crate is not linked. Point the `xla` path \
     dependency at a real checkout to execute HLO artifacts (see README.md), \
     or run on the default native backend instead.";

/// Error type mirroring `xla::Error` (only `Display` is relied upon).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla error: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (tensor) handle.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        unimplemented!("{STUB_MSG}")
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unimplemented!("{STUB_MSG}")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unimplemented!("{STUB_MSG}")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unimplemented!("{STUB_MSG}")
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unimplemented!("{STUB_MSG}")
    }
}

/// A computation ready for PJRT compilation.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        unimplemented!("{STUB_MSG}")
    }
}

/// Device-side buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unimplemented!("{STUB_MSG}")
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unimplemented!("{STUB_MSG}")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unimplemented!("{STUB_MSG}")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unimplemented!("{STUB_MSG}")
    }
}
