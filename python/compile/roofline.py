"""TPU roofline / VMEM-footprint estimator for the L1 Pallas kernels.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so per the
session contract the real-hardware story is *estimated structurally* from
the BlockSpec schedule: VMEM working set per grid step, MXU utilization
(fraction of each (TM,TK)x(TK,TN) block that is real work vs padding), and
arithmetic intensity (FLOPs per HBM byte) against a TPUv4-like roofline
(275 TF/s bf16 ≈ 137 TF/s f32-ish MXU, 1200 GB/s HBM).

Usage:  python -m compile.roofline [--out ../artifacts/roofline.json]
The numbers land in DESIGN.md §Perf / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import argparse
import json

from . import model as M
from .kernels.matmul_fused import default_tiles, vmem_bytes

VMEM_LIMIT = 16 * 1024 * 1024  # bytes per TPUv4 core
PEAK_FLOPS = 137e12  # f32-through-MXU ballpark
HBM_BW = 1.2e12  # bytes/s


def matmul_shapes(p: M.Preset) -> list[tuple[str, int, int, int]]:
    """Every (M, K, N) the model pushes through the Pallas matmul (fwd)."""
    shapes = []
    c, h, w = p.in_shape
    for i, (oc, pad) in enumerate(p.convs, 1):
        oh, ow = h + 2 * pad - 2, w + 2 * pad - 2
        shapes.append((f"conv{i}", p.batch * oh * ow, c * 9, oc))
        h, w, c = oh // 2, ow // 2, oc
    shapes.append(("fc1", p.batch, p.dbar, p.hidden))
    shapes.append(("fc2", p.batch, p.hidden, p.classes))
    return shapes


def analyze(name: str, m: int, k: int, n: int) -> dict:
    tm, tk, tn = default_tiles(m, k, n)
    ceil = lambda a, b: -(-a // b)
    grid = (ceil(m, tm), ceil(n, tn), ceil(k, tk))
    vmem = vmem_bytes(tm, tk, tn)
    # MXU utilization: useful fraction of the padded block volume
    mp, kp, np_ = ceil(m, tm) * tm, ceil(k, tk) * tk, ceil(n, tn) * tn
    util = (m * k * n) / (mp * kp * np_)
    flops = 2.0 * m * k * n
    # HBM traffic: x read once per j-tile, w once per i-tile, o written once
    bytes_hbm = 4.0 * (m * k * grid[1] + k * n * grid[0] + m * n)
    intensity = flops / bytes_hbm
    # roofline: attainable = min(peak * util, intensity * BW)
    attainable = min(PEAK_FLOPS * util, intensity * HBM_BW)
    return {
        "op": name,
        "mkn": [m, k, n],
        "tiles": [tm, tk, tn],
        "grid": list(grid),
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= VMEM_LIMIT,
        "mxu_utilization": round(util, 4),
        "arithmetic_intensity": round(intensity, 2),
        "attainable_tflops": round(attainable / 1e12, 2),
        "bound": "compute" if PEAK_FLOPS * util <= intensity * HBM_BW else "memory",
    }


def report(presets: list[str]) -> dict:
    out = {}
    for name in presets:
        p = M.PRESETS[name]
        ops = [analyze(n, m, k, nn) for (n, m, k, nn) in matmul_shapes(p)]
        total_flops = sum(2.0 * m * k * nn for (_, m, k, nn) in matmul_shapes(p))
        out[name] = {
            "ops": ops,
            "fwd_gflops_per_step": round(total_flops / 1e9, 3),
            "worst_vmem_bytes": max(o["vmem_bytes"] for o in ops),
            "min_mxu_utilization": min(o["mxu_utilization"] for o in ops),
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/roofline.json")
    ap.add_argument("--presets", default="tiny,mnist,cifar,celeba")
    args = ap.parse_args()
    rep = report([s for s in args.presets.split(",") if s])
    with open(args.out, "w") as fh:
        json.dump(rep, fh, indent=1)
    for name, r in rep.items():
        print(f"[roofline] {name}: fwd {r['fwd_gflops_per_step']} GFLOP/step, "
              f"worst VMEM {r['worst_vmem_bytes']/1e6:.2f} MB, "
              f"min MXU util {r['min_mxu_utilization']:.2%}")
    print(f"[roofline] wrote {args.out}")


if __name__ == "__main__":
    main()
