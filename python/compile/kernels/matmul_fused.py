"""L1 Pallas kernel: tiled matmul + bias + activation (the model's compute hot-spot).

Every convolution (via im2col) and every dense layer in the L2 model funnels
through this kernel, so it dominates the lowered HLO's FLOPs.

TPU-style design (see DESIGN.md §Hardware-Adaptation):
  * the (TM, TK) x (TK, TN) block schedule is expressed with BlockSpec index
    maps — the Pallas analogue of the HBM->VMEM staging a CUDA kernel would do
    with threadblocks + shared memory;
  * tiles default to MXU-friendly multiples of 128 (capped by the problem
    size) and are chosen so the working set  (TM*TK + TK*TN + TM*TN) * 4B
    stays far below a 16 MiB VMEM budget;
  * the accumulator lives in the output block across the K grid dimension
    (sequential innermost grid axis), with bias + activation fused into the
    final K step — one HBM write per output tile.

The kernel is lowered with ``interpret=True``: on this image only the CPU PJRT
plugin is available and real TPU lowering would emit a Mosaic custom-call the
CPU client cannot execute.  The interpret path lowers to plain HLO
(while-loop over the grid + dynamic slices), which is exactly what the Rust
runtime loads.

The backward pass is wired with ``jax.custom_vjp`` so that autodiff of the L2
model *also* runs through this kernel (dx = g @ w.T and dw = x.T @ g are
expressed as two more fused-matmul calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget used for tile selection (bytes). Real TPUv4 cores have ~16 MiB;
# we keep the working set under half of it to leave room for double-buffering.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

_ACTIVATIONS = ("none", "relu")


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def default_tiles(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Pick (TM, TK, TN): VMEM-bounded with a small grid.

    On a real TPU the MXU wants TN/TK as multiples of 128 (lane width); the
    CPU interpret path that this image can actually execute pays dearly for
    lane padding (the grid loop copies whole padded blocks), so we align to
    the 8-wide sublane only and cap at the MXU-friendly sizes. The VMEM
    working-set bound below is the constraint that transfers to real
    hardware; see DESIGN.md §Perf for the per-preset footprint estimates.
    """
    tm = min(_ceil_to(m, 8), 4096)
    tn = min(_ceil_to(n, 8), 128)
    tk = min(_ceil_to(k, 8), 2048)
    # shrink TM if the working set exceeds the VMEM budget
    while tm > 8 and 4 * (tm * tk + tk * tn + tm * tn) > VMEM_BUDGET_BYTES:
        tm //= 2
    return tm, tk, tn


def vmem_bytes(tm: int, tk: int, tn: int) -> int:
    """Working-set estimate for one grid step (x, w, o blocks, f32)."""
    return 4 * (tm * tk + tk * tn + tm * tn)


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        r = o_ref[...] + b_ref[...]
        if activation == "relu":
            r = jnp.maximum(r, 0.0)
        o_ref[...] = r


@functools.partial(jax.jit, static_argnames=("activation", "tiles"))
def _matmul_fused_fwd_impl(x, w, b, *, activation: str, tiles=None):
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)
    tm, tk, tn = tiles or default_tiles(m, k, n)

    mp, kp, np_ = _ceil_to(m, tm), _ceil_to(k, tk), _ceil_to(n, tn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n)).reshape(1, np_)
    nk = kp // tk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, activation=activation),
        grid=(mp // tm, np_ // tn, nk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, tn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_fused(x, w, b, activation="none"):
    """``activation(x @ w + b)`` computed by the Pallas tile kernel.

    x: (M, K) f32, w: (K, N) f32, b: (N,) f32. Returns (M, N) f32.
    Differentiable (custom VJP; backward also runs through the kernel).
    """
    return _matmul_fused_fwd_impl(x, w, b, activation=activation)


def _mm_fwd(x, w, b, activation):
    out = _matmul_fused_fwd_impl(x, w, b, activation=activation)
    return out, (x, w, out)


def _mm_bwd(activation, res, g):
    x, w, out = res
    if activation == "relu":
        g = g * (out > 0.0).astype(g.dtype)
    # dx = g @ w.T ; dw = x.T @ g  — both through the same Pallas kernel.
    dx = _matmul_fused_fwd_impl(
        g, w.T, jnp.zeros((w.shape[0],), jnp.float32), activation="none"
    )
    dw = _matmul_fused_fwd_impl(
        x.T, g, jnp.zeros((g.shape[1],), jnp.float32), activation="none"
    )
    db = jnp.sum(g, axis=0)
    return dx, dw, db


matmul_fused.defvjp(_mm_fwd, _mm_bwd)
