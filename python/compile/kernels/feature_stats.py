"""L1 Pallas kernel: fused per-column statistics of the intermediate feature matrix.

FWDP (paper Alg. 2, eqs. 9-10) needs, for F in R^{B x Dbar}:
  * per-column min / max          (feeds channel normalization + FWQ ranges),
  * per-column mean,
  * per-column stddev of the *channel-normalized* features.

A naive port would make four separate passes over F (HBM-bound). This kernel
computes sum, sum-of-squares, min and max in a single VMEM-resident sweep per
column tile — the TPU rethink of the paper's GPU reference, where the stats
were separate torch reductions (see DESIGN.md §Hardware-Adaptation).

Grid: one program per column tile of width TD; the full batch dimension B is
resident in VMEM (B*TD*4 bytes, e.g. 256*256*4 = 256 KiB << 16 MiB).

interpret=True: the CPU PJRT plugin cannot execute Mosaic custom-calls; the
interpret path lowers the same schedule to plain HLO.

The channel-level reduction (eq. 9's per-channel min/max) and the normalized
sigma (eq. 10) are algebraic post-processing on the per-column stats and are
done in the surrounding jax function `feature_stats` so everything lowers into
one HLO module (`feature_stats.hlo.txt`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _stats_kernel(f_ref, sum_ref, sumsq_ref, min_ref, max_ref):
    f = f_ref[...]  # (B, TD) block, VMEM-resident
    sum_ref[...] = jnp.sum(f, axis=0, keepdims=True)
    sumsq_ref[...] = jnp.sum(f * f, axis=0, keepdims=True)
    min_ref[...] = jnp.min(f, axis=0, keepdims=True)
    max_ref[...] = jnp.max(f, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("td",))
def column_stats(f, td: int = 256):
    """Single-pass per-column (sum, sumsq, min, max) of f: (B, D) f32."""
    b, d = f.shape
    td = min(td, _ceil_to(d, 8))
    dp = _ceil_to(d, td)
    # Pad columns so padding never wins min/max: pad with the first row's
    # value replicated (neutral for min/max, excluded later by slicing).
    fp = jnp.pad(f, ((0, 0), (0, dp - d)), mode="edge") if dp != d else f
    grid = (dp // td,)
    spec1 = pl.BlockSpec((1, td), lambda j: (0, j))
    s, ss, mn, mx = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b, td), lambda j: (0, j))],
        out_specs=[spec1, spec1, spec1, spec1],
        out_shape=[jax.ShapeDtypeStruct((1, dp), jnp.float32)] * 4,
        interpret=True,
    )(fp)
    return s[0, :d], ss[0, :d], mn[0, :d], mx[0, :d]


def feature_stats(f, *, num_channels: int):
    """Everything FWDP/FWQ needs from F, in one lowered module.

    f: (B, Dbar) f32 with channel-major layout — column j belongs to channel
    h = j // (Dbar/num_channels), i.e. the paper's index sets I_h are the
    contiguous blocks of size Dbar/H (the flattened (C, H*W) feature map).

    Returns (col_min, col_max, col_mean, sigma_norm) where sigma_norm is the
    stddev of the channel-normalized features (paper eq. 10):
        sigma_norm_i = sigma_raw_i / (f^max_{I_h} - f^min_{I_h})
    using the algebraic identity that min-max normalization is affine, so the
    normalized stddev is the raw stddev scaled by the channel range.
    """
    b, dbar = f.shape
    assert dbar % num_channels == 0, (dbar, num_channels)
    chan = dbar // num_channels

    s, ss, mn, mx = column_stats(f)
    mean = s / b
    var = jnp.maximum(ss / b - mean * mean, 0.0)
    sigma_raw = jnp.sqrt(var)

    ch_min = jnp.min(mn.reshape(num_channels, chan), axis=1)
    ch_max = jnp.max(mx.reshape(num_channels, chan), axis=1)
    ch_range = ch_max - ch_min
    # degenerate channel (constant values): normalized column is constant, so
    # its normalized stddev is 0 — guard the division.
    safe = jnp.where(ch_range > 0.0, ch_range, 1.0)
    sigma_norm = sigma_raw / jnp.repeat(safe, chan)
    sigma_norm = jnp.where(jnp.repeat(ch_range, chan) > 0.0, sigma_norm, 0.0)
    return mn, mx, mean, sigma_norm
