"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package has a reference implementation here written with
nothing but jnp primitives; pytest asserts allclose between kernel and oracle
over a hypothesis-driven sweep of shapes and value distributions.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_fused_ref(x, w, b, activation="none"):
    """activation(x @ w + b) — oracle for kernels.matmul_fused."""
    r = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if activation == "relu":
        r = jnp.maximum(r, 0.0)
    elif activation != "none":
        raise ValueError(activation)
    return r


def column_stats_ref(f):
    """(sum, sumsq, min, max) per column — oracle for kernels.column_stats."""
    return (
        jnp.sum(f, axis=0),
        jnp.sum(f * f, axis=0),
        jnp.min(f, axis=0),
        jnp.max(f, axis=0),
    )


def feature_stats_ref(f, *, num_channels: int):
    """Oracle for kernels.feature_stats: explicit normalize-then-std path.

    Follows the paper literally: build f_norm via eq. (9) with per-channel
    min/max, then take the per-column stddev (eq. 10). The kernel computes the
    same values via the affine identity; both must agree.
    """
    b, dbar = f.shape
    chan = dbar // num_channels
    fc = f.reshape(b, num_channels, chan)
    ch_min = jnp.min(fc, axis=(0, 2))
    ch_max = jnp.max(fc, axis=(0, 2))
    ch_range = ch_max - ch_min
    safe = jnp.where(ch_range > 0.0, ch_range, 1.0)
    f_norm = (fc - ch_min[None, :, None]) / safe[None, :, None]
    f_norm = jnp.where(ch_range[None, :, None] > 0.0, f_norm, 0.0)
    f_norm = f_norm.reshape(b, dbar)
    mu = jnp.mean(f_norm, axis=0)
    sigma = jnp.sqrt(jnp.mean((f_norm - mu) ** 2, axis=0))
    return (
        jnp.min(f, axis=0),
        jnp.max(f, axis=0),
        jnp.mean(f, axis=0),
        sigma,
    )
