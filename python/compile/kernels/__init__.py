# L1: Pallas kernels for the paper's compute hot-spots.
from .matmul_fused import matmul_fused, default_tiles, vmem_bytes  # noqa: F401
from .feature_stats import column_stats, feature_stats  # noqa: F401
