"""L2: the paper's split model (device-side CNN + server-side MLP head) in JAX.

Every conv (via explicit im2col) and dense layer calls the L1 Pallas kernel
``kernels.matmul_fused``, so the whole fwd/bwd lowers into HLO whose FLOPs run
through the kernel. Entry points lowered by aot.py (one HLO module each):

  device_fwd(wd..., x)            -> F (B, Dbar)        — paper eq. (3)
  server_fwd_bwd(ws..., F, y)     -> (loss, correct, grad_ws..., G) — eqs. (4),(5)
  device_bwd(wd..., x, G)         -> grad_wd...          — chain rule, Alg. 1 l.20
  eval_fwd(wd..., ws..., x)       -> logits              — test-set evaluation
  feature_stats(F)                -> (col_min, col_max, col_mean, sigma_norm)

Presets mirror the paper's three scenarios plus a `tiny` preset used by the
Rust integration tests. `mnist` matches the paper exactly: the LeNet variant
of Sec. VII with N_d = 4,800 and N_s = 148,874 parameters and Dbar = 1,152.
`cifar` / `celeba` substitute from-scratch CNNs for the pretrained
ConvNeXt / MobileNetV3 backbones (no ImageNet weights offline — DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul_fused
from .kernels.feature_stats import feature_stats
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    in_shape: Tuple[int, int, int]  # (C, H, W)
    convs: Tuple[Tuple[int, int], ...]  # ((out_ch, pad), ...) 3x3 kernels, pool-2 after each
    hidden: int
    classes: int
    batch: int
    seed: int = 0

    @property
    def feat_map(self) -> Tuple[int, int, int]:
        """Shape (C_out, H_out, W_out) of the device-side output feature map."""
        c, h, w = self.in_shape
        for oc, pad in self.convs:
            h = h + 2 * pad - 2  # 3x3 conv
            w = w + 2 * pad - 2
            h //= 2  # 2x2 max-pool stride 2
            w //= 2
            c = oc
        return c, h, w

    @property
    def dbar(self) -> int:
        c, h, w = self.feat_map
        return c * h * w

    @property
    def num_channels(self) -> int:
        """H in eq. (9): channel count of the intermediate feature map."""
        return self.feat_map[0]


PRESETS = {
    # Rust integration tests: small + fast.
    "tiny": Preset("tiny", (1, 8, 8), ((4, 1), (8, 1)), 16, 4, 8, seed=7),
    # Paper Sec. VII MNIST scenario (exact LeNet-variant split).
    "mnist": Preset("mnist", (1, 28, 28), ((16, 1), (32, 0)), 128, 10, 64, seed=1),
    # CIFAR-100-like scenario (ConvNeXt substituted; Dbar 4096 vs paper 6144).
    "cifar": Preset("cifar", (3, 32, 32), ((32, 1), (64, 1)), 256, 100, 32, seed=2),
    # CelebA-like scenario (MobileNetV3 substituted; binary attribute task).
    "celeba": Preset("celeba", (3, 32, 32), ((24, 1), (40, 1)), 128, 2, 32, seed=3),
}


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def im2col(x, pad: int):
    """Explicit 3x3 im2col with a deterministic (C, KH, KW) column layout.

    x: (B, C, H, W) -> patches (B*OH*OW, C*9), OH = H + 2*pad - 2.
    """
    b, c, h, w = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh, ow = h + 2 * pad - 2, w + 2 * pad - 2
    cols = []
    for di in range(3):
        for dj in range(3):
            cols.append(x[:, :, di : di + oh, dj : dj + ow])
    # (9, B, C, OH, OW) -> (B, OH, OW, C, 9) -> (B*OH*OW, C*9)
    p = jnp.stack(cols, axis=0)
    p = p.transpose(1, 3, 4, 2, 0)
    return p.reshape(b * oh * ow, c * 9), (b, oh, ow)


def conv3x3_relu(x, w, bias, pad: int, mm=matmul_fused):
    """3x3 conv + bias + ReLU through the Pallas matmul. w: (C*9, OC)."""
    patches, (b, oh, ow) = im2col(x, pad)
    out = mm(patches, w, bias, "relu")
    oc = w.shape[1]
    return out.reshape(b, oh, ow, oc).transpose(0, 3, 1, 2)


def maxpool2(x):
    """2x2 max-pool, stride 2, NCHW."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def _softmax_xent(logits, y_onehot):
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y_onehot, axis=-1)).astype(
            jnp.float32
        )
    )
    return loss, correct


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def device_param_specs(p: Preset) -> List[Tuple[str, Tuple[int, ...]]]:
    specs = []
    c = p.in_shape[0]
    for i, (oc, _pad) in enumerate(p.convs, 1):
        specs.append((f"conv{i}_w", (c * 9, oc)))
        specs.append((f"conv{i}_b", (oc,)))
        c = oc
    return specs


def server_param_specs(p: Preset) -> List[Tuple[str, Tuple[int, ...]]]:
    return [
        ("fc1_w", (p.dbar, p.hidden)),
        ("fc1_b", (p.hidden,)),
        ("fc2_w", (p.hidden, p.classes)),
        ("fc2_b", (p.classes,)),
    ]


def init_params(p: Preset):
    """He-normal weights / zero biases, deterministic per preset seed."""
    key = jax.random.PRNGKey(p.seed)

    def init(specs):
        nonlocal key
        out = []
        for name, shape in specs:
            if name.endswith("_b"):
                out.append(jnp.zeros(shape, jnp.float32))
            else:
                key, sub = jax.random.split(key)
                fan_in = shape[0]
                std = (2.0 / fan_in) ** 0.5
                out.append(std * jax.random.normal(sub, shape, jnp.float32))
        return out

    return init(device_param_specs(p)), init(server_param_specs(p))


def param_count(specs) -> int:
    n = 0
    for _, shape in specs:
        sz = 1
        for d in shape:
            sz *= d
        n += sz
    return n


# ---------------------------------------------------------------------------
# model functions (Pallas path and pure-jnp reference path)
# ---------------------------------------------------------------------------

def _device_fwd(wd: list, x, p: Preset, mm):
    i = 0
    for _, pad in p.convs:
        x = conv3x3_relu(x, wd[i], wd[i + 1], pad, mm=mm)
        x = maxpool2(x)
        i += 2
    b = x.shape[0]
    # channel-major flatten: column j belongs to channel j // (h*w) — the
    # paper's contiguous index sets I_h (eq. 9).
    return x.reshape(b, p.dbar)


def _server_fwd(ws: list, f, mm):
    h = mm(f, ws[0], ws[1], "relu")
    return mm(h, ws[2], ws[3], "none")


def device_fwd(wd, x, p: Preset):
    return _device_fwd(list(wd), x, p, matmul_fused)


def server_fwd(ws, f):
    return _server_fwd(list(ws), f, matmul_fused)


def server_fwd_bwd(ws, f, y, _p: Preset = None):
    """PS side of one step: loss, correct count, ∇w_s, and G = ∇_F h (eq. 5)."""
    ws = list(ws)

    def lf(ws_, f_):
        logits = _server_fwd(ws_, f_, matmul_fused)
        loss, correct = _softmax_xent(logits, y)
        return loss, correct

    (loss, correct), (gws, gf) = jax.value_and_grad(
        lf, argnums=(0, 1), has_aux=True
    )(ws, f)
    return (loss, correct, *gws, gf)


def device_bwd(wd, x, g, p: Preset):
    """Device backward: VJP of device_fwd with the (reconstructed) cotangent Ĝ."""
    wd = list(wd)
    _, vjp = jax.vjp(lambda wd_: _device_fwd(wd_, x, p, matmul_fused), wd)
    (gwd,) = vjp(g)
    return tuple(gwd)


def eval_fwd(wd, ws, x, p: Preset):
    return _server_fwd(list(ws), _device_fwd(list(wd), x, p, matmul_fused), matmul_fused)


def stats_entry(f, p: Preset):
    return feature_stats(f, num_channels=p.num_channels)


# pure-jnp reference path (tests only; never lowered) ------------------------

def device_fwd_ref(wd, x, p: Preset):
    return _device_fwd(list(wd), x, p, kref.matmul_fused_ref)


def server_fwd_ref(ws, f):
    return _server_fwd(list(ws), f, kref.matmul_fused_ref)


def server_fwd_bwd_ref(ws, f, y):
    ws = list(ws)

    def lf(ws_, f_):
        logits = _server_fwd(ws_, f_, kref.matmul_fused_ref)
        loss, correct = _softmax_xent(logits, y)
        return loss, correct

    (loss, correct), (gws, gf) = jax.value_and_grad(
        lf, argnums=(0, 1), has_aux=True
    )(ws, f)
    return (loss, correct, *gws, gf)


def device_bwd_ref(wd, x, g, p: Preset):
    wd = list(wd)
    _, vjp = jax.vjp(lambda wd_: _device_fwd(wd_, x, p, kref.matmul_fused_ref), wd)
    (gwd,) = vjp(g)
    return tuple(gwd)
