"""AOT compile path: lower every L2 entry point to HLO *text* + dump params.

Emits, per preset, into ``artifacts/<preset>/``:
  device_fwd.hlo.txt, server_fwd_bwd.hlo.txt, device_bwd.hlo.txt,
  eval_fwd.hlo.txt, feature_stats.hlo.txt, params.bin
plus a global ``artifacts/manifest.json`` describing shapes/layouts for the
Rust runtime.

HLO *text* (NOT ``lowered.compile().serialize()`` and NOT serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the published
``xla`` 0.1.6 crate) rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids, so text round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Python runs ONLY here (``make artifacts``); the Rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def build_preset(p: M.Preset, out_dir: str) -> dict:
    os.makedirs(os.path.join(out_dir, p.name), exist_ok=True)
    d_specs = M.device_param_specs(p)
    s_specs = M.server_param_specs(p)
    nd, ns = len(d_specs), len(s_specs)

    x_s = _sds((p.batch, *p.in_shape))
    f_s = _sds((p.batch, p.dbar))
    y_s = _sds((p.batch, p.classes))
    g_s = _sds((p.batch, p.dbar))
    wd_s = [_sds(s) for _, s in d_specs]
    ws_s = [_sds(s) for _, s in s_specs]

    # Flat-argument wrappers: the Rust side passes a flat &[Literal].
    def e_device_fwd(*a):
        return (M.device_fwd(a[:nd], a[nd], p),)

    def e_server_fwd_bwd(*a):
        return M.server_fwd_bwd(a[:ns], a[ns], a[ns + 1])

    def e_device_bwd(*a):
        return M.device_bwd(a[:nd], a[nd], a[nd + 1], p)

    def e_eval_fwd(*a):
        return (M.eval_fwd(a[:nd], a[nd : nd + ns], a[nd + ns], p),)

    def e_feature_stats(f):
        return M.stats_entry(f, p)

    entries = {
        "device_fwd": (e_device_fwd, [*wd_s, x_s], 1),
        "server_fwd_bwd": (e_server_fwd_bwd, [*ws_s, f_s, y_s], 2 + ns + 1),
        "device_bwd": (e_device_bwd, [*wd_s, x_s, g_s], nd),
        "eval_fwd": (e_eval_fwd, [*wd_s, *ws_s, x_s], 1),
        "feature_stats": (e_feature_stats, [f_s], 4),
    }

    man_entries = {}
    for name, (fn, args, nout) in entries.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        rel = f"{p.name}/{name}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as fh:
            fh.write(text)
        man_entries[name] = {
            "file": rel,
            "num_inputs": len(args),
            "num_outputs": nout,
            "input_shapes": [list(a.shape) for a in args],
        }
        print(f"  {p.name}/{name}: {len(text)} chars, {len(args)} in, {nout} out")

    # Initial parameters: device then server, concatenated f32 little-endian.
    wd, ws = M.init_params(p)
    import numpy as np

    blob = b"".join(
        np.asarray(a, dtype="<f4").tobytes() for a in (*wd, *ws)
    )
    rel_params = f"{p.name}/params.bin"
    with open(os.path.join(out_dir, rel_params), "wb") as fh:
        fh.write(blob)

    c, fh_, fw_ = p.feat_map
    return {
        "batch": p.batch,
        "dbar": p.dbar,
        "num_channels": p.num_channels,
        "chan_size": fh_ * fw_,
        "classes": p.classes,
        "in_shape": list(p.in_shape),
        "hidden": p.hidden,
        "nd_params": M.param_count(d_specs),
        "ns_params": M.param_count(s_specs),
        "device_params": [{"name": n, "shape": list(s)} for n, s in d_specs],
        "server_params": [{"name": n, "shape": list(s)} for n, s in s_specs],
        "params_file": rel_params,
        "entries": man_entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets", default="tiny,mnist,cifar,celeba", help="comma-separated"
    )
    args = ap.parse_args()

    manifest = {"format": 1, "presets": {}}
    for name in args.presets.split(","):
        name = name.strip()
        if not name:
            continue
        print(f"[aot] building preset {name!r}")
        manifest["presets"][name] = build_preset(M.PRESETS[name], args.out_dir)

    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)
    print(f"[aot] wrote {path}")


if __name__ == "__main__":
    main()
