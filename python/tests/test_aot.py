"""AOT pipeline checks: HLO text is parseable-looking, manifest is consistent
with the model presets, params.bin has the right byte length."""

import json
import os

import pytest

from compile import model as M
from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as fh:
        return json.load(fh)


class TestHloText:
    def test_lower_tiny_entry_produces_hlo_text(self):
        import jax, jax.numpy as jnp

        p = M.PRESETS["tiny"]
        f_s = jax.ShapeDtypeStruct((p.batch, p.dbar), jnp.float32)
        lowered = jax.jit(lambda f: M.stats_entry(f, p)).lower(f_s)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_manifest_entries_exist_on_disk(self):
        man = _manifest()
        for preset in man["presets"].values():
            for e in preset["entries"].values():
                path = os.path.join(ART, e["file"])
                assert os.path.exists(path), path
                with open(path) as fh:
                    head = fh.read(64)
                assert head.startswith("HloModule")


class TestManifestConsistency:
    def test_presets_match_model(self):
        man = _manifest()
        for name, mp in man["presets"].items():
            p = M.PRESETS[name]
            assert mp["batch"] == p.batch
            assert mp["dbar"] == p.dbar
            assert mp["num_channels"] == p.num_channels
            assert mp["classes"] == p.classes
            assert mp["nd_params"] == M.param_count(M.device_param_specs(p))
            assert mp["ns_params"] == M.param_count(M.server_param_specs(p))

    def test_params_bin_length(self):
        man = _manifest()
        for name, mp in man["presets"].items():
            n_floats = mp["nd_params"] + mp["ns_params"]
            path = os.path.join(ART, mp["params_file"])
            assert os.path.getsize(path) == 4 * n_floats

    def test_entry_arity(self):
        man = _manifest()
        for name, mp in man["presets"].items():
            nd = len(mp["device_params"])
            ns = len(mp["server_params"])
            e = mp["entries"]
            assert e["device_fwd"]["num_inputs"] == nd + 1
            assert e["server_fwd_bwd"]["num_inputs"] == ns + 2
            assert e["server_fwd_bwd"]["num_outputs"] == 2 + ns + 1
            assert e["device_bwd"]["num_outputs"] == nd
            assert e["feature_stats"]["num_outputs"] == 4

    def test_input_shapes_recorded(self):
        man = _manifest()
        mp = man["presets"]["tiny"]
        df = mp["entries"]["device_fwd"]
        assert df["input_shapes"][-1] == [mp["batch"], *mp["in_shape"]]
