"""Kernel-vs-oracle correctness: hypothesis sweeps of shapes and values.

This is the L1 correctness gate: the Pallas kernels (interpret=True) must
match the pure-jnp oracles in kernels/ref.py over a broad random family of
shapes, paddings (non-tile-multiple dims), activations, and value ranges.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_fused, column_stats, feature_stats, default_tiles, vmem_bytes
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=70)
small_dims = st.integers(min_value=1, max_value=33)
scales = st.sampled_from([1e-3, 1.0, 37.5, 1e3])


def _arr(rng, shape, scale):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


class TestMatmulFused:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=small_dims, act=st.sampled_from(["none", "relu"]),
           scale=scales, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, k, n, act, scale, seed):
        rng = np.random.default_rng(seed)
        x, w, b = _arr(rng, (m, k), scale), _arr(rng, (k, n), scale), _arr(rng, (n,), scale)
        out = matmul_fused(x, w, b, act)
        ref = R.matmul_fused_ref(x, w, b, act)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4 * scale * scale * k)

    def test_exact_tile_multiple(self):
        rng = np.random.default_rng(0)
        x, w, b = _arr(rng, (256, 512), 1.0), _arr(rng, (512, 128), 1.0), _arr(rng, (128,), 1.0)
        np.testing.assert_allclose(
            matmul_fused(x, w, b, "none"), R.matmul_fused_ref(x, w, b, "none"),
            rtol=1e-4, atol=1e-3,
        )

    def test_relu_clamps(self):
        rng = np.random.default_rng(1)
        x, w = _arr(rng, (16, 8), 1.0), _arr(rng, (8, 4), 1.0)
        b = jnp.full((4,), -100.0)
        out = matmul_fused(x, w, b, "relu")
        assert float(jnp.min(out)) == 0.0

    def test_grad_matches_ref(self):
        """custom_vjp path: autodiff through the kernel equals jnp autodiff."""
        rng = np.random.default_rng(2)
        x, w, b = _arr(rng, (9, 7), 1.0), _arr(rng, (7, 5), 1.0), _arr(rng, (5,), 1.0)
        for act in ("none", "relu"):
            def f_kernel(x, w, b):
                return jnp.sum(matmul_fused(x, w, b, act) ** 2)

            def f_ref(x, w, b):
                return jnp.sum(R.matmul_fused_ref(x, w, b, act) ** 2)

            gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
            gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
            for a, r in zip(gk, gr):
                np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)

    def test_default_tiles_vmem_budget(self):
        """Chosen tiles keep the working set under the VMEM budget."""
        for m, k, n in [(50176, 144, 16), (64, 1152, 128), (8192, 512, 512), (1, 1, 1)]:
            tm, tk, tn = default_tiles(m, k, n)
            assert vmem_bytes(tm, tk, tn) <= 8 * 1024 * 1024
            assert tm >= 1 and tk >= 1 and tn >= 1

    def test_mxu_alignment_when_large(self):
        tm, tk, tn = default_tiles(4096, 4096, 4096)
        assert tn % 128 == 0 and tk % 128 == 0


class TestColumnStats:
    @settings(max_examples=25, deadline=None)
    @given(b=st.integers(1, 64), d=st.integers(1, 300),
           scale=scales, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, b, d, scale, seed):
        rng = np.random.default_rng(seed)
        f = _arr(rng, (b, d), scale)
        out = column_stats(f)
        ref = R.column_stats_ref(f)
        for a, r in zip(out, ref):
            np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4 * scale * scale * b)

    def test_constant_columns(self):
        f = jnp.ones((8, 40)) * 3.0
        s, ss, mn, mx = column_stats(f)
        np.testing.assert_allclose(mn, mx)
        np.testing.assert_allclose(s, jnp.full((40,), 24.0))

    def test_single_column(self):
        f = jnp.arange(5.0).reshape(5, 1)
        s, ss, mn, mx = column_stats(f)
        assert float(s[0]) == 10.0 and float(mn[0]) == 0.0 and float(mx[0]) == 4.0


class TestFeatureStats:
    @settings(max_examples=25, deadline=None)
    @given(b=st.integers(2, 32), chan=st.integers(1, 16), h=st.integers(1, 12),
           scale=scales, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, b, chan, h, scale, seed):
        rng = np.random.default_rng(seed)
        f = _arr(rng, (b, h * chan), scale)
        out = feature_stats(f, num_channels=h)
        ref = R.feature_stats_ref(f, num_channels=h)
        for a, r in zip(out, ref):
            np.testing.assert_allclose(a, r, rtol=2e-3, atol=2e-3)

    def test_degenerate_channel(self):
        """A constant channel must produce sigma_norm = 0, not NaN (eq. 9 guard)."""
        rng = np.random.default_rng(3)
        f = jnp.concatenate(
            [jnp.full((8, 4), 2.5), _arr(rng, (8, 4), 1.0)], axis=1
        )
        mn, mx, mean, sigma = feature_stats(f, num_channels=2)
        assert not bool(jnp.any(jnp.isnan(sigma)))
        np.testing.assert_allclose(sigma[:4], jnp.zeros(4))

    def test_sigma_normalized_range(self):
        """Normalized features live in [0,1] so sigma_norm <= 0.5 (paper Fig. 1b)."""
        rng = np.random.default_rng(4)
        f = _arr(rng, (64, 48), 123.0)
        *_, sigma = feature_stats(f, num_channels=6)
        assert float(jnp.max(sigma)) <= 0.5 + 1e-6

    def test_scale_invariance_of_sigma_norm(self):
        """sigma_norm is invariant to per-channel affine rescaling of F."""
        rng = np.random.default_rng(5)
        f = _arr(rng, (16, 20), 1.0)
        *_, s1 = feature_stats(f, num_channels=4)
        *_, s2 = feature_stats(f * 500.0 + 3.0, num_channels=4)
        np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-5)
