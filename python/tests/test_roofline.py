"""Roofline estimator checks: VMEM bounds, utilization sanity, shape math."""

from compile import model as M
from compile import roofline as R


class TestMatmulShapes:
    def test_mnist_conv_shapes(self):
        p = M.PRESETS["mnist"]
        shapes = dict((n, (m, k, nn)) for n, m, k, nn in R.matmul_shapes(p))
        # conv1: B*28*28 patches of 1*9 -> 16 channels
        assert shapes["conv1"] == (p.batch * 28 * 28, 9, 16)
        # conv2 (pad 0 on 14x14): B*12*12 patches of 16*9 -> 32
        assert shapes["conv2"] == (p.batch * 12 * 12, 144, 32)
        assert shapes["fc1"] == (p.batch, 1152, 128)
        assert shapes["fc2"] == (p.batch, 128, 10)

    def test_every_preset_covered(self):
        for name, p in M.PRESETS.items():
            shapes = R.matmul_shapes(p)
            assert len(shapes) == len(p.convs) + 2


class TestAnalyze:
    def test_vmem_within_budget_for_all_presets(self):
        rep = R.report(list(M.PRESETS))
        for name, r in rep.items():
            assert r["worst_vmem_bytes"] <= R.VMEM_LIMIT, name
            for op in r["ops"]:
                assert op["vmem_ok"], (name, op)

    def test_utilization_in_unit_interval(self):
        rep = R.report(["mnist"])
        for op in rep["mnist"]["ops"]:
            assert 0.0 < op["mxu_utilization"] <= 1.0

    def test_attainable_below_peak(self):
        rep = R.report(["cifar"])
        for op in rep["cifar"]["ops"]:
            assert op["attainable_tflops"] <= R.PEAK_FLOPS / 1e12 + 1e-9

    def test_flop_count_matches_hand_calc(self):
        # tiny fc2: 2 * B * hidden * classes
        p = M.PRESETS["tiny"]
        a = R.analyze("fc2", p.batch, p.hidden, p.classes)
        assert a["mkn"] == [p.batch, p.hidden, p.classes]

    def test_bound_classification(self):
        a = R.analyze("big", 8192, 8192, 8192)
        assert a["bound"] in ("compute", "memory")
        # a tiny op is always memory-bound
        b = R.analyze("small", 8, 8, 8)
        assert b["bound"] == "memory"
