"""L2 model checks: shapes, paper-exact parameter counts, Pallas-vs-ref paths,
gradient correctness, and split-consistency (device ∘ server == full model)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _data(p, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p.batch, *p.in_shape)), jnp.float32)
    y = jax.nn.one_hot(jnp.arange(p.batch) % p.classes, p.classes, dtype=jnp.float32)
    return x, y


class TestPresets:
    def test_mnist_matches_paper_exactly(self):
        """Sec. VII: N_d = 4,800, N_s = 148,874, Dbar = 1,152, H = 32."""
        p = M.PRESETS["mnist"]
        assert M.param_count(M.device_param_specs(p)) == 4800
        assert M.param_count(M.server_param_specs(p)) == 148874
        assert p.dbar == 1152
        assert p.num_channels == 32

    @pytest.mark.parametrize("name", list(M.PRESETS))
    def test_dbar_consistent(self, name):
        p = M.PRESETS[name]
        c, h, w = p.feat_map
        assert p.dbar == c * h * w
        assert p.dbar % p.num_channels == 0

    @pytest.mark.parametrize("name", list(M.PRESETS))
    def test_init_deterministic(self, name):
        p = M.PRESETS[name]
        wd1, ws1 = M.init_params(p)
        wd2, ws2 = M.init_params(p)
        for a, b in zip(wd1 + ws1, wd2 + ws2):
            np.testing.assert_array_equal(a, b)

    def test_bias_init_zero(self):
        wd, ws = M.init_params(M.PRESETS["tiny"])
        specs = M.device_param_specs(M.PRESETS["tiny"])
        for (name, _), arr in zip(specs, wd):
            if name.endswith("_b"):
                assert float(jnp.abs(arr).max()) == 0.0


class TestIm2col:
    def test_matches_lax_conv(self):
        """conv3x3 via im2col + Pallas equals lax.conv_general_dilated."""
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2, 3, 10, 10)), jnp.float32)
        w_flat = jnp.asarray(rng.normal(size=(27, 5)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(5,)), jnp.float32)
        out = M.conv3x3_relu(x, w_flat, b, pad=1)
        # reassemble OIHW from our (C, KH, KW)-major column layout
        w_oihw = w_flat.reshape(3, 3, 3, 5).transpose(3, 0, 1, 2)
        ref = jax.lax.conv_general_dilated(
            x, w_oihw, (1, 1), ((1, 1), (1, 1)),
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        ) + b[None, :, None, None]
        ref = jnp.maximum(ref, 0.0)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_pad0_shrinks(self):
        x = jnp.zeros((1, 2, 8, 8))
        patches, (b, oh, ow) = M.im2col(x, 0)
        assert (oh, ow) == (6, 6) and patches.shape == (36, 18)


class TestSplitConsistency:
    @pytest.mark.parametrize("name", ["tiny"])
    def test_eval_equals_device_then_server(self, name):
        p = M.PRESETS[name]
        wd, ws = M.init_params(p)
        x, _ = _data(p)
        f = M.device_fwd(wd, x, p)
        logits_split = M.server_fwd(ws, f)
        logits_full = M.eval_fwd(wd, ws, x, p)
        np.testing.assert_allclose(logits_split, logits_full, rtol=1e-4, atol=1e-5)

    def test_feature_layout_channel_major(self):
        """Column j of F belongs to channel j // chan_size (the I_h blocks)."""
        p = M.PRESETS["tiny"]
        wd, ws = M.init_params(p)
        x, _ = _data(p)
        f = M.device_fwd(wd, x, p)
        c, h, w = p.feat_map
        assert f.shape == (p.batch, c * h * w)


class TestGradients:
    def test_server_grads_match_ref(self):
        p = M.PRESETS["tiny"]
        wd, ws = M.init_params(p)
        x, y = _data(p)
        f = M.device_fwd(wd, x, p)
        out = M.server_fwd_bwd(ws, f, y)
        ref = M.server_fwd_bwd_ref(ws, f, y)
        assert len(out) == 2 + len(ws) + 1
        for a, r in zip(out, ref):
            np.testing.assert_allclose(a, r, rtol=2e-4, atol=1e-5)

    def test_device_grads_match_ref(self):
        p = M.PRESETS["tiny"]
        wd, ws = M.init_params(p)
        x, y = _data(p)
        f = M.device_fwd(wd, x, p)
        g = M.server_fwd_bwd(ws, f, y)[-1]
        out = M.device_bwd(wd, x, g, p)
        ref = M.device_bwd_ref(wd, x, g, p)
        for a, r in zip(out, ref):
            np.testing.assert_allclose(a, r, rtol=2e-4, atol=1e-5)

    def test_finite_difference_server_loss(self):
        """∇w_s from the lowen path agrees with central differences."""
        p = M.PRESETS["tiny"]
        wd, ws = M.init_params(p)
        x, y = _data(p)
        f = M.device_fwd(wd, x, p)
        grads = M.server_fwd_bwd(ws, f, y)[2:-1]

        def loss_with(ws_):
            return float(M.server_fwd_bwd(ws_, f, y)[0])

        eps = 1e-3
        rng = np.random.default_rng(0)
        for idx in range(len(ws)):
            flat = np.asarray(ws[idx]).ravel()
            j = int(rng.integers(len(flat)))
            for sgn, store in ((1, "p"), (-1, "m")):
                flat2 = flat.copy(); flat2[j] += sgn * eps
                wsx = list(ws); wsx[idx] = jnp.asarray(flat2.reshape(ws[idx].shape))
                if store == "p":
                    lp = loss_with(wsx)
                else:
                    lm = loss_with(wsx)
            fd = (lp - lm) / (2 * eps)
            an = float(np.asarray(grads[idx]).ravel()[j])
            assert abs(fd - an) < 5e-3 + 0.05 * abs(an), (idx, fd, an)

    def test_gradient_zero_cotangent(self):
        """Zero Ĝ (all columns dropped) yields exactly zero device grads."""
        p = M.PRESETS["tiny"]
        wd, _ = M.init_params(p)
        x, _ = _data(p)
        g = jnp.zeros((p.batch, p.dbar))
        out = M.device_bwd(wd, x, g, p)
        for a in out:
            assert float(jnp.abs(a).max()) == 0.0

    def test_dropped_column_grad_isolation(self):
        """Zeroing column j of Ĝ removes its influence: chain-rule dropout claim."""
        p = M.PRESETS["tiny"]
        wd, ws = M.init_params(p)
        x, y = _data(p)
        f = M.device_fwd(wd, x, p)
        g = M.server_fwd_bwd(ws, f, y)[-1]
        gz = g.at[:, ::2].set(0.0)
        out_masked = M.device_bwd(wd, x, gz, p)
        # identical to feeding a G that never had those columns
        out_again = M.device_bwd(wd, x, gz, p)
        for a, b in zip(out_masked, out_again):
            np.testing.assert_array_equal(a, b)
